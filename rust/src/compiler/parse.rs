//! Recursive-descent parser for GTaP-C.
//!
//! Enforces the paper's *syntactic* restriction on directives at parse time:
//! `#pragma gtap task` must be immediately followed by a call to a function
//! (optionally as an assignment capturing the return value) — statement
//! blocks are not supported (§5.1.4 "Language/Compiler restrictions").
//! Whether the callee is actually a `#pragma gtap function` is checked by
//! sema, which knows the symbol table.

use super::diag::{CompileError, CompileResult};
use super::lex::{Tok, Token};
use crate::ir::ast::*;
use crate::ir::types::Type;

struct Parser<'a> {
    toks: &'a [Token],
    pos: usize,
}

/// Parse a token stream into an AST.
pub fn parse(tokens: &[Token]) -> CompileResult<Program> {
    let mut p = Parser {
        toks: tokens,
        pos: 0,
    };
    p.program()
}

impl<'a> Parser<'a> {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn peek2(&self) -> &Tok {
        &self.toks[(self.pos + 1).min(self.toks.len() - 1)].tok
    }

    fn span(&self) -> Span {
        self.toks[self.pos].span
    }

    fn bump(&mut self) -> &Token {
        let t = &self.toks[self.pos];
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == t {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, t: &Tok, what: &str) -> CompileResult<Span> {
        let sp = self.span();
        if self.eat(t) {
            Ok(sp)
        } else {
            CompileError::err(sp, format!("expected {what}, found {:?}", self.peek()))
        }
    }

    fn ident(&mut self, what: &str) -> CompileResult<(String, Span)> {
        let sp = self.span();
        match self.peek().clone() {
            Tok::Ident(name) => {
                self.bump();
                Ok((name, sp))
            }
            other => CompileError::err(sp, format!("expected {what}, found {other:?}")),
        }
    }

    fn try_type(&mut self) -> Option<Type> {
        let ty = match self.peek() {
            Tok::KwInt => Type::Int,
            Tok::KwFloat => Type::Float,
            Tok::KwPtr => Type::Ptr,
            Tok::KwVoid => Type::Void,
            _ => return None,
        };
        self.bump();
        Some(ty)
    }

    // ---- top level ------------------------------------------------------

    fn program(&mut self) -> CompileResult<Program> {
        let mut prog = Program::default();
        loop {
            match self.peek() {
                Tok::Eof => return Ok(prog),
                Tok::KwGlobal => prog.globals.push(self.global_decl()?),
                Tok::PragmaFunction => {
                    let sp = self.span();
                    self.bump();
                    self.expect(&Tok::PragmaEnd, "end of pragma line")?;
                    let mut f = self.function(sp)?;
                    f.is_task = true;
                    prog.functions.push(f);
                }
                Tok::PragmaEntry => {
                    return CompileError::err(
                        self.span(),
                        "#pragma gtap entry is host-driven in GTaP-Sim: start the \
                         root task with Session::run(entry, args) instead",
                    );
                }
                Tok::KwInt | Tok::KwFloat | Tok::KwVoid | Tok::KwPtr => {
                    let sp = self.span();
                    let f = self.function(sp)?;
                    prog.functions.push(f);
                }
                other => {
                    return CompileError::err(
                        self.span(),
                        format!("expected declaration, found {other:?}"),
                    )
                }
            }
        }
    }

    fn global_decl(&mut self) -> CompileResult<GlobalDecl> {
        let span = self.span();
        self.expect(&Tok::KwGlobal, "`global`")?;
        let ty = self
            .try_type()
            .ok_or_else(|| CompileError::new(self.span(), "expected type after `global`"))?;
        if ty == Type::Void {
            return CompileError::err(span, "global variables cannot be void");
        }
        let (name, _) = self.ident("global variable name")?;
        self.expect(&Tok::Semi, "';'")?;
        Ok(GlobalDecl { name, ty, span })
    }

    fn function(&mut self, span: Span) -> CompileResult<Function> {
        let ret = self
            .try_type()
            .ok_or_else(|| CompileError::new(self.span(), "expected return type"))?;
        let (name, _) = self.ident("function name")?;
        self.expect(&Tok::LParen, "'('")?;
        let mut params = Vec::new();
        if !self.eat(&Tok::RParen) {
            loop {
                let psp = self.span();
                let ty = self
                    .try_type()
                    .ok_or_else(|| CompileError::new(self.span(), "expected parameter type"))?;
                if ty == Type::Void {
                    return CompileError::err(psp, "parameters cannot be void");
                }
                let (pname, _) = self.ident("parameter name")?;
                params.push(Param {
                    name: pname,
                    ty,
                    span: psp,
                });
                if self.eat(&Tok::RParen) {
                    break;
                }
                self.expect(&Tok::Comma, "',' or ')'")?;
            }
        }
        let body = self.block()?;
        Ok(Function {
            name,
            is_task: false,
            ret,
            params,
            body,
            span,
        })
    }

    // ---- statements -----------------------------------------------------

    fn block(&mut self) -> CompileResult<Block> {
        self.expect(&Tok::LBrace, "'{'")?;
        let mut stmts = Vec::new();
        while !self.eat(&Tok::RBrace) {
            if *self.peek() == Tok::Eof {
                return CompileError::err(self.span(), "unexpected end of file in block");
            }
            stmts.push(self.stmt()?);
        }
        Ok(Block { stmts })
    }

    /// A `{...}` block, or a single statement wrapped in a block.
    fn block_or_stmt(&mut self) -> CompileResult<Block> {
        if *self.peek() == Tok::LBrace {
            self.block()
        } else {
            Ok(Block {
                stmts: vec![self.stmt()?],
            })
        }
    }

    fn stmt(&mut self) -> CompileResult<Stmt> {
        let span = self.span();
        match self.peek().clone() {
            Tok::KwInt | Tok::KwFloat | Tok::KwPtr => {
                let s = self.decl(span)?;
                self.expect(&Tok::Semi, "';'")?;
                Ok(s)
            }
            Tok::PragmaTask => self.spawn_stmt(span),
            Tok::PragmaTaskwait => {
                self.bump();
                let (queue, _) = self.pragma_clauses(false)?;
                self.expect(&Tok::PragmaEnd, "end of pragma line")?;
                Ok(Stmt::TaskWait { queue, span })
            }
            Tok::PragmaFunction => CompileError::err(
                span,
                "#pragma gtap function must appear at top level, before a function definition",
            ),
            Tok::PragmaEntry => CompileError::err(
                span,
                "#pragma gtap entry is host-driven in GTaP-Sim (Session::run)",
            ),
            Tok::KwIf => {
                self.bump();
                self.expect(&Tok::LParen, "'('")?;
                let cond = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                let then_blk = self.block_or_stmt()?;
                let else_blk = if self.eat(&Tok::KwElse) {
                    Some(self.block_or_stmt()?)
                } else {
                    None
                };
                Ok(Stmt::If {
                    cond,
                    then_blk,
                    else_blk,
                    span,
                })
            }
            Tok::KwWhile => {
                self.bump();
                self.expect(&Tok::LParen, "'('")?;
                let cond = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                let body = self.block_or_stmt()?;
                Ok(Stmt::While { cond, body, span })
            }
            Tok::KwFor => {
                self.bump();
                self.expect(&Tok::LParen, "'('")?;
                let init = if *self.peek() == Tok::Semi {
                    None
                } else if matches!(self.peek(), Tok::KwInt | Tok::KwFloat | Tok::KwPtr) {
                    let sp = self.span();
                    Some(Box::new(self.decl(sp)?))
                } else {
                    let sp = self.span();
                    Some(Box::new(self.simple_stmt(sp)?))
                };
                self.expect(&Tok::Semi, "';'")?;
                let cond = if *self.peek() == Tok::Semi {
                    None
                } else {
                    Some(self.expr()?)
                };
                self.expect(&Tok::Semi, "';'")?;
                let step = if *self.peek() == Tok::RParen {
                    None
                } else {
                    let sp = self.span();
                    Some(Box::new(self.simple_stmt(sp)?))
                };
                self.expect(&Tok::RParen, "')'")?;
                let body = self.block_or_stmt()?;
                Ok(Stmt::For {
                    init,
                    cond,
                    step,
                    body,
                    span,
                })
            }
            Tok::KwParallelFor => {
                self.bump();
                self.expect(&Tok::LParen, "'('")?;
                let (var, _) = self.ident("loop variable")?;
                self.expect(&Tok::KwIn, "`in`")?;
                let lo = self.expr()?;
                self.expect(&Tok::DotDot, "'..'")?;
                let hi = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                let body = self.block_or_stmt()?;
                Ok(Stmt::ParallelFor {
                    var,
                    lo,
                    hi,
                    body,
                    span,
                })
            }
            Tok::KwReturn => {
                self.bump();
                let value = if *self.peek() == Tok::Semi {
                    None
                } else {
                    Some(self.expr()?)
                };
                self.expect(&Tok::Semi, "';'")?;
                Ok(Stmt::Return { value, span })
            }
            Tok::LBrace => Ok(Stmt::Nested(self.block()?)),
            _ => {
                let s = self.simple_stmt(span)?;
                self.expect(&Tok::Semi, "';'")?;
                Ok(s)
            }
        }
    }

    fn decl(&mut self, span: Span) -> CompileResult<Stmt> {
        let ty = self.try_type().unwrap();
        if ty == Type::Void {
            return CompileError::err(span, "cannot declare a void variable");
        }
        let (name, _) = self.ident("variable name")?;
        let init = if self.eat(&Tok::Assign) {
            Some(self.expr()?)
        } else {
            None
        };
        Ok(Stmt::Decl {
            name,
            ty,
            init,
            span,
        })
    }

    /// Assignment or expression statement (no trailing `;` consumed — used
    /// in `for` headers too).
    fn simple_stmt(&mut self, span: Span) -> CompileResult<Stmt> {
        let e = self.expr()?;
        let op = match self.peek() {
            Tok::Assign => None,
            Tok::PlusAssign => Some(BinOp::Add),
            Tok::MinusAssign => Some(BinOp::Sub),
            Tok::StarAssign => Some(BinOp::Mul),
            _ => {
                return Ok(Stmt::ExprStmt { expr: e, span });
            }
        };
        self.bump();
        let rhs = self.expr()?;
        let target = self.to_lvalue(&e)?;
        let value = match op {
            None => rhs,
            Some(op) => Expr::Binary {
                op,
                lhs: Box::new(e),
                rhs: Box::new(rhs),
                span,
            },
        };
        Ok(Stmt::Assign {
            target,
            value,
            span,
        })
    }

    fn to_lvalue(&self, e: &Expr) -> CompileResult<LValue> {
        match e {
            Expr::Var(name, _) => Ok(LValue::Var(name.clone())),
            Expr::Index { base, index, .. } => Ok(LValue::Index {
                base: (**base).clone(),
                index: (**index).clone(),
            }),
            other => CompileError::err(other.span(), "invalid assignment target"),
        }
    }

    /// Optional pragma clauses after `task`/`taskwait`: `queue(e)` and —
    /// on `task` only — `priority(e)`. Accepted in any order, at most once
    /// each; a duplicate is a hard error.
    fn pragma_clauses(
        &mut self,
        allow_priority: bool,
    ) -> CompileResult<(Option<Expr>, Option<Expr>)> {
        let mut queue: Option<Expr> = None;
        let mut priority: Option<Expr> = None;
        loop {
            let name = match self.peek() {
                Tok::Ident(n) => n.clone(),
                _ => break,
            };
            let slot = match name.as_str() {
                "queue" => &mut queue,
                "priority" if allow_priority => &mut priority,
                "priority" => {
                    return CompileError::err(
                        self.span(),
                        "priority(expr) applies to #pragma gtap task only \
                         (a continuation re-enters at its own task's band)",
                    )
                }
                _ => break,
            };
            if slot.is_some() {
                return CompileError::err(
                    self.span(),
                    format!("duplicate {name}(...) clause in pragma"),
                );
            }
            self.bump();
            self.expect(&Tok::LParen, "'(' after clause name")?;
            let e = self.expr()?;
            self.expect(&Tok::RParen, "')'")?;
            *slot = Some(e);
        }
        Ok((queue, priority))
    }

    /// `#pragma gtap task [queue(e)] [priority(e)]` followed by
    /// `x = f(a);` or `f(a);`.
    fn spawn_stmt(&mut self, span: Span) -> CompileResult<Stmt> {
        self.bump(); // PragmaTask
        let (queue, priority) = self.pragma_clauses(true)?;
        self.expect(&Tok::PragmaEnd, "end of pragma line")?;

        // Restricted form: [ident =] call ;
        let stmt_span = self.span();
        if !matches!(self.peek(), Tok::Ident(_)) {
            return CompileError::err(
                stmt_span,
                "#pragma gtap task must be immediately followed by a call to a \
                 task function (optionally as an assignment); statement blocks \
                 are not supported",
            );
        }
        let e = self.expr()?;
        let (dest, call_expr) = if self.eat(&Tok::Assign) {
            let dest = match &e {
                Expr::Var(name, _) => name.clone(),
                _ => {
                    return CompileError::err(
                        stmt_span,
                        "#pragma gtap task assignment target must be a plain variable",
                    )
                }
            };
            let rhs = self.expr()?;
            (Some(dest), rhs)
        } else {
            (None, e)
        };
        self.expect(&Tok::Semi, "';'")?;
        let call = match call_expr {
            Expr::Call(c) => c,
            other => {
                return CompileError::err(
                    other.span(),
                    "#pragma gtap task must be immediately followed by a call to a \
                     task function (optionally as an assignment); statement blocks \
                     are not supported",
                )
            }
        };
        Ok(Stmt::Spawn {
            queue,
            priority,
            dest,
            call,
            span,
        })
    }

    // ---- expressions (C precedence) --------------------------------------

    fn expr(&mut self) -> CompileResult<Expr> {
        self.ternary()
    }

    fn ternary(&mut self) -> CompileResult<Expr> {
        let cond = self.logic_or()?;
        if self.eat(&Tok::Question) {
            let span = self.span();
            let then_e = self.expr()?;
            self.expect(&Tok::Colon, "':'")?;
            let else_e = self.ternary()?;
            Ok(Expr::Ternary {
                cond: Box::new(cond),
                then_e: Box::new(then_e),
                else_e: Box::new(else_e),
                span,
            })
        } else {
            Ok(cond)
        }
    }

    fn binary_level(
        &mut self,
        ops: &[(Tok, BinOp)],
        next: fn(&mut Self) -> CompileResult<Expr>,
    ) -> CompileResult<Expr> {
        let mut lhs = next(self)?;
        'outer: loop {
            for (tok, op) in ops {
                if self.peek() == tok {
                    let span = self.span();
                    self.bump();
                    let rhs = next(self)?;
                    lhs = Expr::Binary {
                        op: *op,
                        lhs: Box::new(lhs),
                        rhs: Box::new(rhs),
                        span,
                    };
                    continue 'outer;
                }
            }
            return Ok(lhs);
        }
    }

    fn logic_or(&mut self) -> CompileResult<Expr> {
        self.binary_level(&[(Tok::OrOr, BinOp::LOr)], Self::logic_and)
    }

    fn logic_and(&mut self) -> CompileResult<Expr> {
        self.binary_level(&[(Tok::AndAnd, BinOp::LAnd)], Self::bit_or)
    }

    fn bit_or(&mut self) -> CompileResult<Expr> {
        self.binary_level(&[(Tok::Pipe, BinOp::Or)], Self::bit_xor)
    }

    fn bit_xor(&mut self) -> CompileResult<Expr> {
        self.binary_level(&[(Tok::Caret, BinOp::Xor)], Self::bit_and)
    }

    fn bit_and(&mut self) -> CompileResult<Expr> {
        self.binary_level(&[(Tok::Amp, BinOp::And)], Self::equality)
    }

    fn equality(&mut self) -> CompileResult<Expr> {
        self.binary_level(
            &[(Tok::EqEq, BinOp::Eq), (Tok::Ne, BinOp::Ne)],
            Self::relational,
        )
    }

    fn relational(&mut self) -> CompileResult<Expr> {
        self.binary_level(
            &[
                (Tok::Lt, BinOp::Lt),
                (Tok::Le, BinOp::Le),
                (Tok::Gt, BinOp::Gt),
                (Tok::Ge, BinOp::Ge),
            ],
            Self::shift,
        )
    }

    fn shift(&mut self) -> CompileResult<Expr> {
        self.binary_level(
            &[(Tok::Shl, BinOp::Shl), (Tok::Shr, BinOp::Shr)],
            Self::additive,
        )
    }

    fn additive(&mut self) -> CompileResult<Expr> {
        self.binary_level(
            &[(Tok::Plus, BinOp::Add), (Tok::Minus, BinOp::Sub)],
            Self::multiplicative,
        )
    }

    fn multiplicative(&mut self) -> CompileResult<Expr> {
        self.binary_level(
            &[
                (Tok::Star, BinOp::Mul),
                (Tok::Slash, BinOp::Div),
                (Tok::Percent, BinOp::Rem),
            ],
            Self::unary,
        )
    }

    fn unary(&mut self) -> CompileResult<Expr> {
        let span = self.span();
        let op = match self.peek() {
            Tok::Minus => Some(UnOp::Neg),
            Tok::Tilde => Some(UnOp::BitNot),
            Tok::Bang => Some(UnOp::Not),
            _ => None,
        };
        if let Some(op) = op {
            self.bump();
            let e = self.unary()?;
            return Ok(Expr::Unary {
                op,
                expr: Box::new(e),
                span,
            });
        }
        // cast: '(' type ')' unary
        if *self.peek() == Tok::LParen {
            if let Tok::KwInt | Tok::KwFloat | Tok::KwPtr = self.peek2() {
                // lookahead for `( type )`
                let save = self.pos;
                self.bump(); // (
                let ty = self.try_type().unwrap();
                if self.eat(&Tok::RParen) {
                    let e = self.unary()?;
                    return Ok(Expr::Cast {
                        ty,
                        expr: Box::new(e),
                        span,
                    });
                }
                self.pos = save;
            }
        }
        self.postfix()
    }

    fn postfix(&mut self) -> CompileResult<Expr> {
        let mut e = self.primary()?;
        loop {
            let span = self.span();
            if self.eat(&Tok::LBracket) {
                let index = self.expr()?;
                self.expect(&Tok::RBracket, "']'")?;
                e = Expr::Index {
                    base: Box::new(e),
                    index: Box::new(index),
                    span,
                };
            } else {
                return Ok(e);
            }
        }
    }

    fn primary(&mut self) -> CompileResult<Expr> {
        let span = self.span();
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(Expr::IntLit(v))
            }
            Tok::Float(v) => {
                self.bump();
                Ok(Expr::FloatLit(v))
            }
            Tok::Ident(name) => {
                self.bump();
                if self.eat(&Tok::LParen) {
                    let mut args = Vec::new();
                    if !self.eat(&Tok::RParen) {
                        loop {
                            args.push(self.expr()?);
                            if self.eat(&Tok::RParen) {
                                break;
                            }
                            self.expect(&Tok::Comma, "',' or ')'")?;
                        }
                    }
                    Ok(Expr::Call(CallExpr {
                        callee: name,
                        args,
                        span,
                    }))
                } else {
                    Ok(Expr::Var(name, span))
                }
            }
            Tok::LParen => {
                self.bump();
                let e = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(e)
            }
            other => CompileError::err(span, format!("expected expression, found {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::lex::lex;

    fn parse_src(src: &str) -> CompileResult<Program> {
        parse(&lex(src).unwrap())
    }

    #[test]
    fn parses_fib_program4() {
        let src = r#"
            global int d_result;
            #pragma gtap function
            device int fib(int n) {
                if (n < 2) return n;
                int a; int b;
                #pragma gtap task queue((n - 1) < 2 ? 1 : 0)
                a = fib(n - 1);
                #pragma gtap task queue((n - 2) < 2 ? 1 : 0)
                b = fib(n - 2);
                #pragma gtap taskwait queue(2)
                return a + b;
            }
        "#;
        let prog = parse_src(src).unwrap();
        assert_eq!(prog.globals.len(), 1);
        assert_eq!(prog.functions.len(), 1);
        let f = &prog.functions[0];
        assert!(f.is_task);
        assert_eq!(f.name, "fib");
        assert_eq!(f.params.len(), 1);
        // body: if, decl, decl, spawn, spawn, taskwait, return
        assert_eq!(f.body.stmts.len(), 7);
        assert!(matches!(&f.body.stmts[3], Stmt::Spawn { dest: Some(d), queue: Some(_), .. } if d == "a"));
        assert!(matches!(&f.body.stmts[5], Stmt::TaskWait { queue: Some(_), .. }));
    }

    #[test]
    fn spawn_without_capture() {
        let prog = parse_src(
            "#pragma gtap function\nvoid bfs(int v) {\n#pragma gtap task\nbfs(v);\n}",
        )
        .unwrap();
        assert!(
            matches!(&prog.functions[0].body.stmts[0], Stmt::Spawn { dest: None, queue: None, .. })
        );
    }

    #[test]
    fn spawn_priority_clause_parses_in_any_order() {
        let prog = parse_src(
            "#pragma gtap function\nvoid f(int n) {\n\
             #pragma gtap task priority(n) queue(1)\nf(n - 1);\n\
             #pragma gtap task queue(0) priority(2)\nf(n - 2);\n}",
        )
        .unwrap();
        for s in &prog.functions[0].body.stmts {
            assert!(
                matches!(s, Stmt::Spawn { queue: Some(_), priority: Some(_), .. }),
                "{s:?}"
            );
        }
    }

    #[test]
    fn duplicate_pragma_clause_rejected() {
        let err = parse_src(
            "#pragma gtap function\nvoid f(int n) {\n\
             #pragma gtap task priority(1) priority(2)\nf(n);\n}",
        )
        .unwrap_err();
        assert!(err.message.contains("duplicate"), "{err}");
    }

    #[test]
    fn priority_on_taskwait_rejected() {
        let err = parse_src(
            "#pragma gtap function\nvoid f(int n) {\n\
             #pragma gtap taskwait priority(1)\n}",
        )
        .unwrap_err();
        assert!(err.message.contains("gtap task only"), "{err}");
    }

    #[test]
    fn spawn_requires_call() {
        let err = parse_src("#pragma gtap function\nvoid f() {\n#pragma gtap task\nint x = 3;\n}")
            .unwrap_err();
        assert!(err.message.contains("immediately followed"), "{err}");
    }

    #[test]
    fn spawn_block_rejected() {
        let err =
            parse_src("#pragma gtap function\nvoid f() {\n#pragma gtap task\n{ f(); }\n}")
                .unwrap_err();
        assert!(err.message.contains("task"), "{err}");
    }

    #[test]
    fn for_loop_and_compound_assign() {
        let prog = parse_src("void f(int n) { for (int i = 0; i < n; i += 1) { n = n - 1; } }")
            .unwrap();
        assert!(matches!(&prog.functions[0].body.stmts[0], Stmt::For { .. }));
    }

    #[test]
    fn parallel_for() {
        let prog =
            parse_src("void f(int n) { parallel_for (i in 0..n) { print_int(i); } }").unwrap();
        assert!(
            matches!(&prog.functions[0].body.stmts[0], Stmt::ParallelFor { var, .. } if var == "i")
        );
    }

    #[test]
    fn ternary_precedence() {
        let prog = parse_src("int f(int n) { return n < 2 ? 1 : 0; }").unwrap();
        match &prog.functions[0].body.stmts[0] {
            Stmt::Return { value: Some(Expr::Ternary { .. }), .. } => {}
            other => panic!("expected ternary return, got {other:?}"),
        }
    }

    #[test]
    fn cast_vs_paren() {
        let prog = parse_src("float f(int n) { return (float) n; }").unwrap();
        match &prog.functions[0].body.stmts[0] {
            Stmt::Return { value: Some(Expr::Cast { ty: Type::Float, .. }), .. } => {}
            other => panic!("expected cast, got {other:?}"),
        }
        // parenthesized expression still works
        parse_src("int f(int n) { return (n + 1) * 2; }").unwrap();
    }

    #[test]
    fn index_lvalue() {
        let prog = parse_src("void f(ptr p, int i) { p[i] = p[i + 1]; }").unwrap();
        assert!(
            matches!(&prog.functions[0].body.stmts[0], Stmt::Assign { target: LValue::Index { .. }, .. })
        );
    }

    #[test]
    fn entry_pragma_rejected_with_hint() {
        let err = parse_src("#pragma gtap entry\nint f() { return 0; }").unwrap_err();
        assert!(err.message.contains("Session::run"), "{err}");
    }

    #[test]
    fn nested_blocks() {
        let prog = parse_src("void f() { { int x = 1; } }").unwrap();
        assert!(matches!(&prog.functions[0].body.stmts[0], Stmt::Nested(_)));
    }

    #[test]
    fn missing_semi_errors() {
        assert!(parse_src("void f() { int x = 1 }").is_err());
    }

    #[test]
    fn global_void_rejected() {
        assert!(parse_src("global void g;").is_err());
    }
}
