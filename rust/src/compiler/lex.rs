//! Lexer for GTaP-C.
//!
//! Mostly a conventional C-style tokenizer; the one specialty is pragma
//! handling. A line of the form `#pragma gtap <kind> …` is turned into a
//! `Pragma*` token, the remainder of the line is tokenized normally (so
//! `queue((n - 1) < 2 ? 1 : 0)` is ordinary tokens) and a `PragmaEnd` token
//! is emitted at the end of that line — pragmas are line-oriented, exactly
//! as in C.

use super::diag::{CompileError, CompileResult};
use crate::ir::ast::Span;

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // literals and identifiers
    Int(i64),
    Float(f64),
    Ident(String),
    // keywords
    KwInt,
    KwFloat,
    KwVoid,
    KwPtr,
    KwGlobal,
    KwReturn,
    KwIf,
    KwElse,
    KwWhile,
    KwFor,
    KwParallelFor,
    KwIn,
    // pragmas
    PragmaFunction,
    PragmaTask,
    PragmaTaskwait,
    PragmaEntry,
    PragmaEnd,
    // punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Colon,
    Question,
    DotDot,
    // operators
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Amp,
    Pipe,
    Caret,
    Tilde,
    Bang,
    Shl,
    Shr,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    Ne,
    AndAnd,
    OrOr,
    Assign,
    PlusAssign,
    MinusAssign,
    StarAssign,
    Eof,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
    /// When true we are inside a pragma line: a newline emits `PragmaEnd`.
    in_pragma: bool,
    out: Vec<Token>,
}

/// Tokenize GTaP-C source.
pub fn lex(source: &str) -> CompileResult<Vec<Token>> {
    let mut lx = Lexer {
        src: source.as_bytes(),
        pos: 0,
        line: 1,
        col: 1,
        in_pragma: false,
        out: Vec::new(),
    };
    lx.run()?;
    Ok(lx.out)
}

impl<'a> Lexer<'a> {
    fn span(&self) -> Span {
        Span {
            line: self.line,
            col: self.col,
        }
    }

    fn peek(&self) -> u8 {
        *self.src.get(self.pos).unwrap_or(&0)
    }

    fn peek2(&self) -> u8 {
        *self.src.get(self.pos + 1).unwrap_or(&0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek();
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        c
    }

    fn push(&mut self, tok: Tok, span: Span) {
        self.out.push(Token { tok, span });
    }

    fn run(&mut self) -> CompileResult<()> {
        loop {
            // whitespace & comments
            loop {
                let c = self.peek();
                if c == b'\n' && self.in_pragma {
                    let sp = self.span();
                    self.bump();
                    self.in_pragma = false;
                    self.push(Tok::PragmaEnd, sp);
                    continue;
                }
                if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
                    self.bump();
                    continue;
                }
                if c == b'/' && self.peek2() == b'/' {
                    while self.peek() != b'\n' && self.peek() != 0 {
                        self.bump();
                    }
                    continue;
                }
                if c == b'/' && self.peek2() == b'*' {
                    let sp = self.span();
                    self.bump();
                    self.bump();
                    loop {
                        if self.peek() == 0 {
                            return CompileError::err(sp, "unterminated block comment");
                        }
                        if self.peek() == b'*' && self.peek2() == b'/' {
                            self.bump();
                            self.bump();
                            break;
                        }
                        self.bump();
                    }
                    continue;
                }
                break;
            }

            let sp = self.span();
            let c = self.peek();
            if c == 0 {
                if self.in_pragma {
                    self.push(Tok::PragmaEnd, sp);
                    self.in_pragma = false;
                }
                self.push(Tok::Eof, sp);
                return Ok(());
            }

            if c == b'#' {
                self.lex_pragma(sp)?;
                continue;
            }
            if c.is_ascii_digit() || (c == b'.' && self.peek2().is_ascii_digit()) {
                self.lex_number(sp)?;
                continue;
            }
            if c.is_ascii_alphabetic() || c == b'_' {
                self.lex_ident(sp);
                continue;
            }
            self.lex_punct(sp)?;
        }
    }

    fn lex_pragma(&mut self, sp: Span) -> CompileResult<()> {
        // consume '#', expect "pragma gtap <kind>"
        self.bump();
        let mut words = Vec::new();
        for _ in 0..3 {
            while self.peek() == b' ' || self.peek() == b'\t' {
                self.bump();
            }
            let mut w = String::new();
            while self.peek().is_ascii_alphanumeric() || self.peek() == b'_' {
                w.push(self.bump() as char);
            }
            words.push(w);
        }
        if words[0] != "pragma" || words[1] != "gtap" {
            return CompileError::err(sp, format!("unsupported preprocessor directive: #{}", words[0]));
        }
        let tok = match words[2].as_str() {
            "function" => Tok::PragmaFunction,
            "task" => Tok::PragmaTask,
            "taskwait" => Tok::PragmaTaskwait,
            "entry" => Tok::PragmaEntry,
            other => {
                return CompileError::err(
                    sp,
                    format!("unknown gtap pragma {other:?} (expected function/task/taskwait/entry)"),
                )
            }
        };
        self.push(tok, sp);
        self.in_pragma = true; // rest of the line (e.g. queue(...)) lexes normally
        Ok(())
    }

    fn lex_number(&mut self, sp: Span) -> CompileResult<()> {
        let start = self.pos;
        // hex?
        if self.peek() == b'0' && (self.peek2() == b'x' || self.peek2() == b'X') {
            self.bump();
            self.bump();
            let hstart = self.pos;
            while self.peek().is_ascii_hexdigit() {
                self.bump();
            }
            let text = std::str::from_utf8(&self.src[hstart..self.pos]).unwrap();
            let v = i64::from_str_radix(text, 16)
                .map_err(|e| CompileError::new(sp, format!("bad hex literal: {e}")))?;
            self.push(Tok::Int(v), sp);
            return Ok(());
        }
        let mut is_float = false;
        while self.peek().is_ascii_digit() {
            self.bump();
        }
        if self.peek() == b'.' && self.peek2() != b'.' {
            // not the `..` range operator
            is_float = true;
            self.bump();
            while self.peek().is_ascii_digit() {
                self.bump();
            }
        }
        if self.peek() == b'e' || self.peek() == b'E' {
            is_float = true;
            self.bump();
            if self.peek() == b'+' || self.peek() == b'-' {
                self.bump();
            }
            while self.peek().is_ascii_digit() {
                self.bump();
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        if is_float {
            let v: f64 = text
                .parse()
                .map_err(|e| CompileError::new(sp, format!("bad float literal {text:?}: {e}")))?;
            self.push(Tok::Float(v), sp);
        } else {
            let v: i64 = text
                .parse()
                .map_err(|e| CompileError::new(sp, format!("bad int literal {text:?}: {e}")))?;
            self.push(Tok::Int(v), sp);
        }
        Ok(())
    }

    fn lex_ident(&mut self, sp: Span) {
        let start = self.pos;
        while self.peek().is_ascii_alphanumeric() || self.peek() == b'_' {
            self.bump();
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        let tok = match text {
            "int" => Tok::KwInt,
            "float" => Tok::KwFloat,
            "void" => Tok::KwVoid,
            "ptr" => Tok::KwPtr,
            "global" => Tok::KwGlobal,
            "return" => Tok::KwReturn,
            "if" => Tok::KwIf,
            "else" => Tok::KwElse,
            "while" => Tok::KwWhile,
            "for" => Tok::KwFor,
            "parallel_for" => Tok::KwParallelFor,
            "in" => Tok::KwIn,
            // `device` is accepted and ignored for CUDA-source affinity
            // (`__device__` functions in the paper's listings).
            "device" | "__device__" => return self.lex_after_device(),
            _ => Tok::Ident(text.to_string()),
        };
        self.push(tok, sp);
    }

    fn lex_after_device(&mut self) {
        // `device` / `__device__` qualifiers are a no-op; nothing emitted.
    }

    fn lex_punct(&mut self, sp: Span) -> CompileResult<()> {
        let c = self.bump();
        let two = |lx: &mut Lexer, second: u8, yes: Tok, no: Tok| {
            if lx.peek() == second {
                lx.bump();
                yes
            } else {
                no
            }
        };
        let tok = match c {
            b'(' => Tok::LParen,
            b')' => Tok::RParen,
            b'{' => Tok::LBrace,
            b'}' => Tok::RBrace,
            b'[' => Tok::LBracket,
            b']' => Tok::RBracket,
            b',' => Tok::Comma,
            b';' => Tok::Semi,
            b':' => Tok::Colon,
            b'?' => Tok::Question,
            b'~' => Tok::Tilde,
            b'^' => Tok::Caret,
            b'+' => two(self, b'=', Tok::PlusAssign, Tok::Plus),
            b'-' => two(self, b'=', Tok::MinusAssign, Tok::Minus),
            b'*' => two(self, b'=', Tok::StarAssign, Tok::Star),
            b'/' => Tok::Slash,
            b'%' => Tok::Percent,
            b'.' => {
                if self.peek() == b'.' {
                    self.bump();
                    Tok::DotDot
                } else {
                    return CompileError::err(sp, "unexpected '.'");
                }
            }
            b'&' => two(self, b'&', Tok::AndAnd, Tok::Amp),
            b'|' => two(self, b'|', Tok::OrOr, Tok::Pipe),
            b'!' => two(self, b'=', Tok::Ne, Tok::Bang),
            b'=' => two(self, b'=', Tok::EqEq, Tok::Assign),
            b'<' => {
                if self.peek() == b'<' {
                    self.bump();
                    Tok::Shl
                } else {
                    two(self, b'=', Tok::Le, Tok::Lt)
                }
            }
            b'>' => {
                if self.peek() == b'>' {
                    self.bump();
                    Tok::Shr
                } else {
                    two(self, b'=', Tok::Ge, Tok::Gt)
                }
            }
            other => {
                return CompileError::err(
                    sp,
                    format!("unexpected character {:?}", other as char),
                )
            }
        };
        self.push(tok, sp);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn basic_tokens() {
        assert_eq!(
            toks("int x = 42;"),
            vec![
                Tok::KwInt,
                Tok::Ident("x".into()),
                Tok::Assign,
                Tok::Int(42),
                Tok::Semi,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn float_and_hex_literals() {
        assert_eq!(
            toks("1.5 0x1F 2e3"),
            vec![Tok::Float(1.5), Tok::Int(31), Tok::Float(2000.0), Tok::Eof]
        );
    }

    #[test]
    fn operators() {
        assert_eq!(
            toks("a <= b >> 2 && c != d"),
            vec![
                Tok::Ident("a".into()),
                Tok::Le,
                Tok::Ident("b".into()),
                Tok::Shr,
                Tok::Int(2),
                Tok::AndAnd,
                Tok::Ident("c".into()),
                Tok::Ne,
                Tok::Ident("d".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn pragma_line() {
        let t = toks("#pragma gtap task queue(1)\nx = f(2);");
        assert_eq!(t[0], Tok::PragmaTask);
        assert_eq!(t[1], Tok::Ident("queue".into()));
        assert_eq!(t[2], Tok::LParen);
        assert_eq!(t[3], Tok::Int(1));
        assert_eq!(t[4], Tok::RParen);
        assert_eq!(t[5], Tok::PragmaEnd);
        assert_eq!(t[6], Tok::Ident("x".into()));
    }

    #[test]
    fn pragma_at_eof_gets_end() {
        let t = toks("#pragma gtap taskwait");
        assert_eq!(t[0], Tok::PragmaTaskwait);
        assert_eq!(t[1], Tok::PragmaEnd);
        assert_eq!(t[2], Tok::Eof);
    }

    #[test]
    fn comments_skipped() {
        assert_eq!(
            toks("// line\nint /* block\nspanning */ x"),
            vec![Tok::KwInt, Tok::Ident("x".into()), Tok::Eof]
        );
    }

    #[test]
    fn device_qualifier_ignored() {
        assert_eq!(
            toks("device int fib(int n)"),
            vec![
                Tok::KwInt,
                Tok::Ident("fib".into()),
                Tok::LParen,
                Tok::KwInt,
                Tok::Ident("n".into()),
                Tok::RParen,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn range_operator_not_float() {
        assert_eq!(
            toks("0..n"),
            vec![Tok::Int(0), Tok::DotDot, Tok::Ident("n".into()), Tok::Eof]
        );
    }

    #[test]
    fn unknown_pragma_rejected() {
        assert!(lex("#pragma omp parallel").is_err());
        assert!(lex("#pragma gtap bogus").is_err());
    }

    #[test]
    fn spans_track_lines() {
        let ts = lex("int\nx").unwrap();
        assert_eq!(ts[0].span.line, 1);
        assert_eq!(ts[1].span.line, 2);
    }

    #[test]
    fn unexpected_char_errors() {
        assert!(lex("int @x").is_err());
        assert!(lex("/* unterminated").is_err());
    }
}
