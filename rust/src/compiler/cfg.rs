//! Statement-level control-flow graph over the (sema-checked) AST.
//!
//! The paper computes its spill sets "on the CFG using standard backward
//! data-flow analysis" (§5.2.3); this module builds that CFG. Nodes are
//! atomic statements or conditions with use/def sets over alpha-renamed
//! variable names; structured control flow (if/while/for/parallel_for)
//! becomes the usual edges, and every `taskwait` gets its own node so the
//! liveness pass can read off "live immediately after each taskwait".

use crate::ir::ast::*;
use std::collections::HashMap;

pub type NodeId = usize;
pub type VarId = usize;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Plain statement (decl/assign/spawn/exprstmt/return).
    Stmt,
    /// Branch condition (if/while/for/parallel_for header).
    Cond,
    /// `taskwait` suspension point; `index` is the 1-based state number.
    TaskWait { index: usize },
    /// Synthetic function entry/exit.
    Entry,
    Exit,
}

#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub uses: Vec<VarId>,
    pub defs: Vec<VarId>,
    pub succs: Vec<NodeId>,
}

/// Control-flow graph of one task function.
#[derive(Clone, Debug)]
pub struct Cfg {
    pub nodes: Vec<Node>,
    pub entry: NodeId,
    pub exit: NodeId,
    /// Interned variable names (alpha-renamed, so globally unique).
    pub vars: Vec<String>,
    var_ids: HashMap<String, VarId>,
    /// Node of each taskwait, in source (pre-order) order — the same order
    /// codegen assigns state numbers, so `taskwaits[k]` is state `k+1`.
    pub taskwaits: Vec<NodeId>,
}

impl Cfg {
    pub fn var_id(&self, name: &str) -> Option<VarId> {
        self.var_ids.get(name).copied()
    }

    fn intern(&mut self, name: &str) -> VarId {
        if let Some(&id) = self.var_ids.get(name) {
            return id;
        }
        let id = self.vars.len();
        self.vars.push(name.to_string());
        self.var_ids.insert(name.to_string(), id);
        id
    }

    fn add(&mut self, kind: NodeKind) -> NodeId {
        self.nodes.push(Node {
            kind,
            uses: vec![],
            defs: vec![],
            succs: vec![],
        });
        self.nodes.len() - 1
    }

    fn edge(&mut self, from: NodeId, to: NodeId) {
        if !self.nodes[from].succs.contains(&to) {
            self.nodes[from].succs.push(to);
        }
    }

    /// Build the CFG of a task function body.
    pub fn build(func: &Function) -> Cfg {
        let mut cfg = Cfg {
            nodes: vec![],
            entry: 0,
            exit: 0,
            vars: vec![],
            var_ids: HashMap::new(),
            taskwaits: vec![],
        };
        cfg.entry = cfg.add(NodeKind::Entry);
        cfg.exit = cfg.add(NodeKind::Exit);
        for p in &func.params {
            cfg.intern(&p.name);
        }
        let exit = cfg.exit;
        let tails = cfg.build_block(&func.body, vec![cfg.entry]);
        for t in tails {
            cfg.edge(t, exit);
        }
        cfg
    }

    /// Lower a block: `preds` are the dangling predecessors; returns the new
    /// dangling tails (empty when all paths returned).
    fn build_block(&mut self, block: &Block, mut preds: Vec<NodeId>) -> Vec<NodeId> {
        for s in &block.stmts {
            if preds.is_empty() {
                // unreachable code after return — still build nodes so that
                // use/def information exists, but leave them disconnected.
                preds = vec![];
            }
            preds = self.build_stmt(s, preds);
        }
        preds
    }

    fn connect(&mut self, preds: &[NodeId], to: NodeId) {
        for &p in preds {
            self.edge(p, to);
        }
    }

    fn build_stmt(&mut self, s: &Stmt, preds: Vec<NodeId>) -> Vec<NodeId> {
        match s {
            Stmt::Decl { name, init, .. } => {
                let n = self.add(NodeKind::Stmt);
                if let Some(e) = init {
                    self.uses_of_expr(e, n);
                }
                let v = self.intern(name);
                self.nodes[n].defs.push(v);
                self.connect(&preds, n);
                vec![n]
            }
            Stmt::Assign { target, value, .. } => {
                let n = self.add(NodeKind::Stmt);
                self.uses_of_expr(value, n);
                match target {
                    LValue::Var(name) => {
                        let v = self.intern(name);
                        self.nodes[n].defs.push(v);
                    }
                    LValue::Global(_) => {}
                    LValue::Index { base, index } => {
                        self.uses_of_expr(base, n);
                        self.uses_of_expr(index, n);
                    }
                }
                self.connect(&preds, n);
                vec![n]
            }
            Stmt::ExprStmt { expr, .. } => {
                let n = self.add(NodeKind::Stmt);
                self.uses_of_expr(expr, n);
                self.connect(&preds, n);
                vec![n]
            }
            Stmt::Spawn {
                queue,
                priority,
                call,
                ..
            } => {
                // dest is NOT defined here: the child's result materializes
                // at the taskwait re-entry (ChildResult), see liveness.
                let n = self.add(NodeKind::Stmt);
                for a in &call.args {
                    self.uses_of_expr(a, n);
                }
                if let Some(q) = queue {
                    self.uses_of_expr(q, n);
                }
                if let Some(p) = priority {
                    self.uses_of_expr(p, n);
                }
                self.connect(&preds, n);
                vec![n]
            }
            Stmt::TaskWait { queue, .. } => {
                let index = self.taskwaits.len() + 1;
                let n = self.add(NodeKind::TaskWait { index });
                if let Some(q) = queue {
                    self.uses_of_expr(q, n);
                }
                self.taskwaits.push(n);
                self.connect(&preds, n);
                vec![n]
            }
            Stmt::Return { value, .. } => {
                let n = self.add(NodeKind::Stmt);
                if let Some(e) = value {
                    self.uses_of_expr(e, n);
                }
                self.connect(&preds, n);
                let exit = self.exit;
                self.edge(n, exit);
                vec![] // no fallthrough
            }
            Stmt::If {
                cond,
                then_blk,
                else_blk,
                ..
            } => {
                let c = self.add(NodeKind::Cond);
                self.uses_of_expr(cond, c);
                self.connect(&preds, c);
                let mut tails = self.build_block(then_blk, vec![c]);
                match else_blk {
                    Some(e) => {
                        let mut et = self.build_block(e, vec![c]);
                        tails.append(&mut et);
                    }
                    None => tails.push(c),
                }
                tails
            }
            Stmt::While { cond, body, .. } => {
                let c = self.add(NodeKind::Cond);
                self.uses_of_expr(cond, c);
                self.connect(&preds, c);
                let tails = self.build_block(body, vec![c]);
                self.connect(&tails, c); // back edge
                vec![c]
            }
            Stmt::For {
                init,
                cond,
                step,
                body,
                ..
            } => {
                let mut preds = preds;
                if let Some(i) = init {
                    preds = self.build_stmt(i, preds);
                }
                let c = self.add(NodeKind::Cond);
                if let Some(e) = cond {
                    self.uses_of_expr(e, c);
                }
                self.connect(&preds, c);
                let mut tails = self.build_block(body, vec![c]);
                if let Some(st) = step {
                    tails = self.build_stmt(st, tails);
                }
                self.connect(&tails, c); // back edge
                vec![c]
            }
            Stmt::ParallelFor {
                var, lo, hi, body, ..
            } => {
                // Model as a loop: header defines the induction var and uses
                // the bounds; body may iterate many times (back edge).
                let h = self.add(NodeKind::Cond);
                self.uses_of_expr(lo, h);
                self.uses_of_expr(hi, h);
                let v = self.intern(var);
                self.nodes[h].defs.push(v);
                self.connect(&preds, h);
                let tails = self.build_block(body, vec![h]);
                self.connect(&tails, h);
                vec![h]
            }
            Stmt::Nested(b) => self.build_block(b, preds),
        }
    }

    fn uses_of_expr(&mut self, e: &Expr, node: NodeId) {
        match e {
            Expr::IntLit(_) | Expr::FloatLit(_) | Expr::Global(..) => {}
            Expr::Var(name, _) => {
                let v = self.intern(name);
                if !self.nodes[node].uses.contains(&v) {
                    self.nodes[node].uses.push(v);
                }
            }
            Expr::Unary { expr, .. } | Expr::Cast { expr, .. } => {
                self.uses_of_expr(expr, node)
            }
            Expr::Binary { lhs, rhs, .. } => {
                self.uses_of_expr(lhs, node);
                self.uses_of_expr(rhs, node);
            }
            Expr::Ternary {
                cond,
                then_e,
                else_e,
                ..
            } => {
                self.uses_of_expr(cond, node);
                self.uses_of_expr(then_e, node);
                self.uses_of_expr(else_e, node);
            }
            Expr::Call(c) => {
                for a in &c.args {
                    self.uses_of_expr(a, node);
                }
            }
            Expr::Index { base, index, .. } => {
                self.uses_of_expr(base, node);
                self.uses_of_expr(index, node);
            }
        }
    }

    /// Predecessor lists (computed on demand for the backward analysis).
    pub fn preds(&self) -> Vec<Vec<NodeId>> {
        let mut preds = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &s in &n.succs {
                preds[s].push(i);
            }
        }
        preds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{lex::lex, parse::parse, sema::analyze};

    fn cfg_of(src: &str) -> Cfg {
        let checked = analyze(parse(&lex(src).unwrap()).unwrap()).unwrap();
        Cfg::build(&checked.tasks[0].func)
    }

    #[test]
    fn straight_line_chain() {
        let cfg = cfg_of("#pragma gtap function\nvoid f(int n) { int x = n; x = x + 1; }");
        // entry -> decl -> assign -> exit
        assert_eq!(cfg.nodes.len(), 4);
        let decl = 2;
        assert_eq!(cfg.nodes[cfg.entry].succs, vec![decl]);
        assert_eq!(cfg.nodes[decl].succs, vec![3]);
        assert_eq!(cfg.nodes[3].succs, vec![cfg.exit]);
    }

    #[test]
    fn if_then_else_merges() {
        let cfg = cfg_of(
            "#pragma gtap function\nvoid f(int n) { int x = 0; if (n) { x = 1; } else { x = 2; } x = x; }",
        );
        // Both arms must flow into the final assignment.
        let last_assign = cfg
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind == NodeKind::Stmt)
            .map(|(i, _)| i)
            .max()
            .unwrap();
        let preds = cfg.preds();
        assert_eq!(preds[last_assign].len(), 2);
    }

    #[test]
    fn while_has_back_edge() {
        let cfg = cfg_of("#pragma gtap function\nvoid f(int n) { while (n) { n = n - 1; } }");
        let cond = cfg
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Cond)
            .unwrap();
        let body = cfg.nodes[cond]
            .succs
            .iter()
            .copied()
            .find(|&s| cfg.nodes[s].kind == NodeKind::Stmt)
            .unwrap();
        assert!(cfg.nodes[body].succs.contains(&cond), "missing back edge");
    }

    #[test]
    fn taskwait_nodes_indexed_in_order() {
        let cfg = cfg_of(
            "#pragma gtap function\nvoid t() { return; }\n\
             #pragma gtap function\nvoid f() {\n#pragma gtap task\nt();\n\
             #pragma gtap taskwait\n#pragma gtap task\nt();\n#pragma gtap taskwait\n}",
        );
        assert_eq!(cfg.taskwaits.len(), 0); // first function is `t`
        let checked = analyze(
            parse(
                &lex("#pragma gtap function\nvoid t() { return; }\n\
                      #pragma gtap function\nvoid f() {\n#pragma gtap task\nt();\n\
                      #pragma gtap taskwait\n#pragma gtap task\nt();\n#pragma gtap taskwait\n}")
                .unwrap(),
            )
            .unwrap(),
        )
        .unwrap();
        let cfg_f = Cfg::build(&checked.tasks[1].func);
        assert_eq!(cfg_f.taskwaits.len(), 2);
        assert_eq!(
            cfg_f.nodes[cfg_f.taskwaits[0]].kind,
            NodeKind::TaskWait { index: 1 }
        );
        assert_eq!(
            cfg_f.nodes[cfg_f.taskwaits[1]].kind,
            NodeKind::TaskWait { index: 2 }
        );
    }

    #[test]
    fn return_cuts_fallthrough() {
        let cfg = cfg_of(
            "#pragma gtap function\nint f(int n) { if (n < 2) return n; return n + 1; }",
        );
        // The first return's only successor is exit.
        let ret1 = cfg
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Stmt && n.succs == vec![cfg.exit])
            .unwrap();
        assert!(cfg.nodes[ret1].uses.len() == 1);
    }

    #[test]
    fn spawn_does_not_define_dest() {
        let checked = analyze(
            parse(
                &lex("#pragma gtap function\nint t(int n) { return n; }\n\
                      #pragma gtap function\nint f(int n) { int a;\n#pragma gtap task\n\
                      a = t(n);\n#pragma gtap taskwait\nreturn a; }")
                .unwrap(),
            )
            .unwrap(),
        )
        .unwrap();
        let cfg = Cfg::build(&checked.tasks[1].func);
        let a = cfg.var_id("a").unwrap();
        for n in &cfg.nodes {
            if n.kind == NodeKind::Stmt {
                assert!(
                    !n.defs.contains(&a) || n.uses.is_empty(),
                    "spawn node must not def its capture dest"
                );
            }
        }
    }

    #[test]
    fn parallel_for_models_loop() {
        let cfg = cfg_of(
            "#pragma gtap function\nvoid f(int n) { parallel_for (i in 0..n) { print_int(i); } }",
        );
        let header = cfg
            .nodes
            .iter()
            .position(|n| n.kind == NodeKind::Cond)
            .unwrap();
        let preds = cfg.preds();
        // header has a predecessor inside the body (back edge)
        assert!(preds[header].len() >= 2);
    }
}
