//! `gtapc` — the GTaP pragma compiler (§5 of the paper).
//!
//! The original is a Clang extension that rewrites the CUDA device AST; this
//! is a self-contained frontend for **GTaP-C**, a C dialect covering the
//! paper's benchmark programs, with the same pragma surface:
//!
//! ```text
//! #pragma gtap function          → task function (state-machine converted)
//! #pragma gtap task [queue(e)]   → spawn the immediately following call
//! #pragma gtap taskwait [queue(e)] → join all direct children since the
//!                                    previous taskwait; continuation
//!                                    re-enters at the generated state
//! ```
//!
//! Pipeline (one module per stage):
//!
//! 1. [`lex`] — tokens, with pragma-aware line handling.
//! 2. [`parse`] — recursive-descent parser → [`crate::ir::ast`].
//! 3. [`sema`] — name resolution with alpha-renaming, type checking, device
//!    function inlining, and enforcement of the paper's §5.1.4 restrictions
//!    (task/entry must immediately precede a task-function call; capturing
//!    spawns must be joined in the same straight-line region; block-level
//!    `parallel_for` rules).
//! 4. [`cfg`] + [`liveness`] — statement-level control-flow graph and
//!    backward data-flow analysis, computing the paper's two conservative
//!    spill criteria (§5.2.3): values live immediately after each taskwait,
//!    and values declared before a taskwait that may be referenced after it.
//! 5. [`codegen`] — state-machine conversion (§5.2.2): one bytecode function
//!    per task function with a state-entry ("switch") table, returns
//!    normalized to `__gtap_finish_task`, spilled variables rewritten to
//!    task-data loads/stores.
//! 6. [`pretty`] — renders the transformed program as Program-6-style
//!    pseudo-C (`gtap compile --emit-c`), used by golden tests and docs.

pub mod cfg;
pub mod codegen;
pub mod diag;
pub mod lex;
pub mod liveness;
pub mod parse;
pub mod pretty;
pub mod sema;

pub use diag::{CompileError, CompileResult};

use crate::ir::Module;

/// Compile GTaP-C source text to a bytecode [`Module`].
///
/// `max_task_data_bytes` enforces `GTAP_MAX_TASK_DATA_SIZE` (Table 1).
pub fn compile(source: &str, max_task_data_bytes: usize) -> CompileResult<Module> {
    let tokens = lex::lex(source)?;
    let ast = parse::parse(&tokens)?;
    let checked = sema::analyze(ast)?;
    codegen::generate(&checked, max_task_data_bytes)
}

/// Compile with the default `GTAP_MAX_TASK_DATA_SIZE` (256 bytes, generous
/// for every paper benchmark).
pub fn compile_default(source: &str) -> CompileResult<Module> {
    compile(source, crate::coordinator::config::DEFAULT_MAX_TASK_DATA_SIZE)
}
