//! Semantic analysis: name resolution (with alpha-renaming so downstream
//! passes are scope-free), type checking with int→float promotion, and
//! enforcement of the paper's §5.1.4 restrictions:
//!
//! * `#pragma gtap task` may only spawn `#pragma gtap function` functions;
//!   conversely, task functions may not be called as ordinary calls.
//! * Non-task ("device") functions are restricted to pure helpers — a
//!   sequence of initialized declarations followed by a single `return` —
//!   and are expanded inline by codegen (serial leaf work belongs in
//!   intrinsics, mirroring the paper's factoring of cutoff bodies).
//! * A value-capturing spawn (`a = fib(n-1);`) must be joined by a
//!   `taskwait` in the same straight-line region so that the compile-time
//!   child slot of `__gtap_load_result(slot)` matches the dynamic spawn
//!   order (the paper has the same implicit requirement: "the parent must
//!   not use the return value until the corresponding taskwait").
//! * `taskwait` inside `parallel_for` is rejected (block-level taskwait must
//!   be reached uniformly by the block, §5.1.3).

use super::diag::{CompileError, CompileResult};
use crate::ir::ast::*;
use crate::ir::intrinsics;
use crate::ir::types::Type;
use std::collections::{HashMap, HashSet};

/// Output of sema: renamed + promoted AST with per-function type tables.
#[derive(Clone, Debug)]
pub struct CheckedProgram {
    pub globals: Vec<GlobalDecl>,
    /// Task functions (`#pragma gtap function`), in source order.
    pub tasks: Vec<TypedFunction>,
    /// Device helper functions, by name (inlined by codegen).
    pub devices: HashMap<String, TypedFunction>,
}

#[derive(Clone, Debug)]
pub struct TypedFunction {
    pub func: Function,
    /// Types of all (uniquely-named) locals and parameters.
    pub var_types: HashMap<String, Type>,
}

impl CheckedProgram {
    pub fn task(&self, name: &str) -> Option<&TypedFunction> {
        self.tasks.iter().find(|t| t.func.name == name)
    }
}

struct FnSig {
    params: Vec<Type>,
    ret: Type,
    is_task: bool,
}

struct Analyzer {
    globals: HashMap<String, Type>,
    fns: HashMap<String, FnSig>,
}

/// Run semantic analysis over a parsed program.
pub fn analyze(prog: Program) -> CompileResult<CheckedProgram> {
    let mut globals = HashMap::new();
    for g in &prog.globals {
        if globals.insert(g.name.clone(), g.ty).is_some() {
            return CompileError::err(g.span, format!("duplicate global {:?}", g.name));
        }
    }
    let mut fns = HashMap::new();
    for f in &prog.functions {
        if intrinsics::lookup(&f.name).is_some() {
            return CompileError::err(
                f.span,
                format!("{:?} shadows a builtin intrinsic", f.name),
            );
        }
        if fns
            .insert(
                f.name.clone(),
                FnSig {
                    params: f.params.iter().map(|p| p.ty).collect(),
                    ret: f.ret,
                    is_task: f.is_task,
                },
            )
            .is_some()
        {
            return CompileError::err(f.span, format!("duplicate function {:?}", f.name));
        }
    }
    let an = Analyzer { globals, fns };

    let mut tasks = Vec::new();
    let mut devices = HashMap::new();
    for f in prog.functions {
        let checked = an.check_function(f)?;
        if checked.func.is_task {
            tasks.push(checked);
        } else {
            an.check_device_shape(&checked)?;
            devices.insert(checked.func.name.clone(), checked);
        }
    }
    an.check_device_acyclic(&devices)?;
    Ok(CheckedProgram {
        globals: prog.globals,
        tasks,
        devices,
    })
}

/// Scope stack for alpha-renaming.
struct Scopes {
    stack: Vec<HashMap<String, String>>,
    used: HashSet<String>,
    var_types: HashMap<String, Type>,
}

impl Scopes {
    fn new() -> Scopes {
        Scopes {
            stack: vec![HashMap::new()],
            used: HashSet::new(),
            var_types: HashMap::new(),
        }
    }

    fn push(&mut self) {
        self.stack.push(HashMap::new());
    }

    fn pop(&mut self) {
        self.stack.pop();
    }

    fn declare(&mut self, name: &str, ty: Type, span: Span) -> CompileResult<String> {
        if self.stack.last().unwrap().contains_key(name) {
            return CompileError::err(
                span,
                format!("{name:?} already declared in this scope"),
            );
        }
        let mut unique = name.to_string();
        let mut k = 1;
        while !self.used.insert(unique.clone()) {
            k += 1;
            unique = format!("{name}@{k}");
        }
        self.stack
            .last_mut()
            .unwrap()
            .insert(name.to_string(), unique.clone());
        self.var_types.insert(unique.clone(), ty);
        Ok(unique)
    }

    fn resolve(&self, name: &str) -> Option<&str> {
        self.stack
            .iter()
            .rev()
            .find_map(|s| s.get(name))
            .map(|s| s.as_str())
    }

    fn type_of(&self, unique: &str) -> Type {
        self.var_types[unique]
    }
}

/// Maximum parameters of a task function (spawn requests are fixed-size in
/// the runtime hot path; mirrors `sim::interp::MAX_TASK_ARGS`).
pub const MAX_TASK_PARAMS: usize = 8;

impl Analyzer {
    fn check_function(&self, f: Function) -> CompileResult<TypedFunction> {
        if f.is_task && f.params.len() > MAX_TASK_PARAMS {
            return CompileError::err(
                f.span,
                format!(
                    "task function {:?} has {} parameters; at most {MAX_TASK_PARAMS} are supported (pack extra state into a task-data pointer)",
                    f.name,
                    f.params.len()
                ),
            );
        }
        let mut sc = Scopes::new();
        let mut params = Vec::new();
        for p in &f.params {
            let unique = sc.declare(&p.name, p.ty, p.span)?;
            params.push(Param {
                name: unique,
                ty: p.ty,
                span: p.span,
            });
        }
        let mut ctx = FnCtx {
            an: self,
            sc,
            ret: f.ret,
            is_task: f.is_task,
            in_parfor: 0,
        };
        let body = ctx.check_block(f.body, true)?;
        let var_types = ctx.sc.var_types;
        Ok(TypedFunction {
            func: Function {
                name: f.name,
                is_task: f.is_task,
                ret: f.ret,
                params,
                body,
                span: f.span,
            },
            var_types,
        })
    }

    /// Device helpers must be a sequence of initialized decls followed by a
    /// single `return expr;` (no control flow) — codegen inlines them.
    fn check_device_shape(&self, tf: &TypedFunction) -> CompileResult<()> {
        let f = &tf.func;
        let n = f.body.stmts.len();
        for (i, s) in f.body.stmts.iter().enumerate() {
            let ok = match s {
                Stmt::Decl { init, .. } => init.is_some() && i + 1 < n,
                Stmt::Return { value, .. } => {
                    i + 1 == n && (value.is_some() == (f.ret != Type::Void))
                }
                Stmt::ExprStmt { .. } => i + 1 < n,
                _ => false,
            };
            if !ok {
                return CompileError::err(
                    s.span(),
                    format!(
                        "device function {:?} must be initialized declarations followed \
                         by a single return (factor serial leaf work into intrinsics, \
                         or mark the function `#pragma gtap function`)",
                        f.name
                    ),
                );
            }
        }
        if n == 0 && f.ret != Type::Void {
            return CompileError::err(f.span, "non-void device function with empty body");
        }
        Ok(())
    }

    /// Reject (mutually) recursive device helpers: codegen expands them
    /// inline, so cycles would not terminate.
    fn check_device_acyclic(
        &self,
        devices: &HashMap<String, TypedFunction>,
    ) -> CompileResult<()> {
        fn calls_in(block: &Block, out: &mut Vec<(String, Span)>) {
            visit_stmts(block, &mut |s| {
                fn expr_calls(e: &Expr, out: &mut Vec<(String, Span)>) {
                    match e {
                        Expr::Call(c) => {
                            out.push((c.callee.clone(), c.span));
                            for a in &c.args {
                                expr_calls(a, out);
                            }
                        }
                        Expr::Unary { expr, .. } | Expr::Cast { expr, .. } => {
                            expr_calls(expr, out)
                        }
                        Expr::Binary { lhs, rhs, .. } => {
                            expr_calls(lhs, out);
                            expr_calls(rhs, out);
                        }
                        Expr::Ternary {
                            cond,
                            then_e,
                            else_e,
                            ..
                        } => {
                            expr_calls(cond, out);
                            expr_calls(then_e, out);
                            expr_calls(else_e, out);
                        }
                        Expr::Index { base, index, .. } => {
                            expr_calls(base, out);
                            expr_calls(index, out);
                        }
                        _ => {}
                    }
                }
                match s {
                    Stmt::Decl { init: Some(e), .. } => expr_calls(e, out),
                    Stmt::Assign { value, .. } => expr_calls(value, out),
                    Stmt::Return { value: Some(e), .. } => expr_calls(e, out),
                    Stmt::ExprStmt { expr, .. } => expr_calls(expr, out),
                    _ => {}
                }
            });
        }
        // DFS cycle detection over the device-call graph.
        let mut color: HashMap<&str, u8> = HashMap::new(); // 1=on stack, 2=done
        fn dfs<'a>(
            name: &'a str,
            devices: &'a HashMap<String, TypedFunction>,
            color: &mut HashMap<&'a str, u8>,
            collect: &dyn Fn(&Block, &mut Vec<(String, Span)>),
        ) -> CompileResult<()> {
            color.insert(name, 1);
            let mut calls = Vec::new();
            collect(&devices[name].func.body, &mut calls);
            for (callee, span) in calls {
                if let Some(tf) = devices.get(callee.as_str()) {
                    match color.get(tf.func.name.as_str()) {
                        Some(1) => {
                            return CompileError::err(
                                span,
                                format!(
                                    "recursive device function {callee:?} cannot be \
                                     inlined; use an intrinsic or a task function"
                                ),
                            )
                        }
                        Some(2) => {}
                        _ => {
                            let key = devices.get_key_value(callee.as_str()).unwrap().0;
                            dfs(key, devices, color, collect)?
                        }
                    }
                }
            }
            *color.get_mut(name).unwrap() = 2;
            Ok(())
        }
        let names: Vec<&str> = devices.keys().map(|s| s.as_str()).collect();
        for name in names {
            if !color.contains_key(name) {
                dfs(name, devices, &mut color, &calls_in)?;
            }
        }
        Ok(())
    }
}

struct FnCtx<'a> {
    an: &'a Analyzer,
    sc: Scopes,
    ret: Type,
    is_task: bool,
    in_parfor: u32,
}

impl<'a> FnCtx<'a> {
    fn check_block(&mut self, block: Block, top: bool) -> CompileResult<Block> {
        if !top {
            self.sc.push();
        }
        let mut out = Vec::with_capacity(block.stmts.len());
        // Pending value-capturing spawns awaiting their straight-line
        // taskwait (cleared at the taskwait; checked at block end).
        let mut pending_capture: Option<Span> = None;
        for s in block.stmts {
            let is_simple = matches!(
                s,
                Stmt::Decl { .. } | Stmt::Assign { .. } | Stmt::ExprStmt { .. } | Stmt::Spawn { .. }
            );
            if pending_capture.is_some() && !is_simple && !matches!(s, Stmt::TaskWait { .. }) {
                return CompileError::err(
                    s.span(),
                    "control flow between a value-capturing spawn and its taskwait: \
                     the capturing spawn's child slot must match dynamic spawn order \
                     (keep capturing spawns and their taskwait in one straight-line \
                     region)",
                );
            }
            match &s {
                Stmt::Spawn { dest: Some(_), span, .. } => {
                    pending_capture.get_or_insert(*span);
                }
                Stmt::TaskWait { .. } => {
                    pending_capture = None;
                }
                _ => {}
            }
            out.push(self.check_stmt(s)?);
        }
        if let Some(span) = pending_capture {
            return CompileError::err(
                span,
                "value-capturing spawn is never joined: add `#pragma gtap taskwait` \
                 in the same block before it ends",
            );
        }
        if !top {
            self.sc.pop();
        }
        Ok(Block { stmts: out })
    }

    fn check_stmt(&mut self, s: Stmt) -> CompileResult<Stmt> {
        match s {
            Stmt::Decl {
                name,
                ty,
                init,
                span,
            } => {
                let init = match init {
                    Some(e) => {
                        let (e, ety) = self.check_expr(e)?;
                        Some(self.coerce(e, ety, ty, span)?)
                    }
                    None => None,
                };
                let unique = self.sc.declare(&name, ty, span)?;
                Ok(Stmt::Decl {
                    name: unique,
                    ty,
                    init,
                    span,
                })
            }
            Stmt::Assign {
                target,
                value,
                span,
            } => {
                let (value, vty) = self.check_expr(value)?;
                let (target, tty) = self.check_lvalue(target, span)?;
                let value = self.coerce(value, vty, tty, span)?;
                Ok(Stmt::Assign {
                    target,
                    value,
                    span,
                })
            }
            Stmt::If {
                cond,
                then_blk,
                else_blk,
                span,
            } => {
                let cond = self.check_cond(cond, span)?;
                let then_blk = self.check_block(then_blk, false)?;
                let else_blk = match else_blk {
                    Some(b) => Some(self.check_block(b, false)?),
                    None => None,
                };
                Ok(Stmt::If {
                    cond,
                    then_blk,
                    else_blk,
                    span,
                })
            }
            Stmt::While { cond, body, span } => {
                let cond = self.check_cond(cond, span)?;
                let body = self.check_block(body, false)?;
                Ok(Stmt::While { cond, body, span })
            }
            Stmt::For {
                init,
                cond,
                step,
                body,
                span,
            } => {
                // The for-header introduces a scope for its decl.
                self.sc.push();
                let init = match init {
                    Some(s) => Some(Box::new(self.check_stmt(*s)?)),
                    None => None,
                };
                let cond = match cond {
                    Some(c) => Some(self.check_cond(c, span)?),
                    None => None,
                };
                let step = match step {
                    Some(s) => Some(Box::new(self.check_stmt(*s)?)),
                    None => None,
                };
                let body = self.check_block(body, false)?;
                self.sc.pop();
                Ok(Stmt::For {
                    init,
                    cond,
                    step,
                    body,
                    span,
                })
            }
            Stmt::Return { value, span } => {
                if self.in_parfor > 0 {
                    return CompileError::err(span, "return inside parallel_for");
                }
                let value = match (value, self.ret) {
                    (None, Type::Void) => None,
                    (Some(_e), Type::Void) => {
                        return CompileError::err(span, "void function returning a value")
                    }
                    (None, _) => {
                        return CompileError::err(span, "non-void function must return a value")
                    }
                    (Some(e), rt) => {
                        let (e, ety) = self.check_expr(e)?;
                        Some(self.coerce(e, ety, rt, span)?)
                    }
                };
                Ok(Stmt::Return { value, span })
            }
            Stmt::ExprStmt { expr, span } => {
                // Must be a call (we have no other side-effecting exprs).
                match &expr {
                    Expr::Call(c) => {
                        if self.an.fns.get(&c.callee).map(|s| s.is_task) == Some(true) {
                            return CompileError::err(
                                span,
                                format!(
                                    "task function {:?} may only be invoked via \
                                     #pragma gtap task",
                                    c.callee
                                ),
                            );
                        }
                    }
                    _ => {
                        return CompileError::err(span, "expression statement has no effect")
                    }
                }
                let (expr, _) = self.check_expr(expr)?;
                Ok(Stmt::ExprStmt { expr, span })
            }
            Stmt::Spawn {
                queue,
                priority,
                dest,
                call,
                span,
            } => {
                if !self.is_task {
                    return CompileError::err(
                        span,
                        "#pragma gtap task may only appear inside a #pragma gtap function",
                    );
                }
                let sig = self.an.fns.get(&call.callee).ok_or_else(|| {
                    CompileError::new(span, format!("unknown task function {:?}", call.callee))
                })?;
                if !sig.is_task {
                    return CompileError::err(
                        span,
                        format!(
                            "{:?} is not a task function (annotate it with \
                             #pragma gtap function)",
                            call.callee
                        ),
                    );
                }
                if call.args.len() != sig.params.len() {
                    return CompileError::err(
                        span,
                        format!(
                            "{:?} expects {} arguments, got {}",
                            call.callee,
                            sig.params.len(),
                            call.args.len()
                        ),
                    );
                }
                let ret = sig.ret;
                let ptypes = sig.params.clone();
                let mut args = Vec::new();
                for (a, pt) in call.args.into_iter().zip(ptypes) {
                    let sp = a.span();
                    let (a, aty) = self.check_expr(a)?;
                    args.push(self.coerce(a, aty, pt, sp)?);
                }
                let dest = match dest {
                    Some(d) => {
                        if ret == Type::Void {
                            return CompileError::err(
                                span,
                                format!("cannot capture result of void task {:?}", call.callee),
                            );
                        }
                        let unique = self.sc.resolve(&d).ok_or_else(|| {
                            CompileError::new(span, format!("unknown variable {d:?}"))
                        })?;
                        let dty = self.sc.type_of(unique);
                        if dty != ret {
                            return CompileError::err(
                                span,
                                format!(
                                    "spawn result type mismatch: {:?} is {dty}, {:?} \
                                     returns {ret}",
                                    d, call.callee
                                ),
                            );
                        }
                        Some(unique.to_string())
                    }
                    None => None,
                };
                let queue = match queue {
                    Some(q) => {
                        let qs = q.span();
                        let (q, qt) = self.check_expr(q)?;
                        if qt != Type::Int {
                            return CompileError::err(qs, "queue(expr) must be int");
                        }
                        Some(q)
                    }
                    None => None,
                };
                let priority = match priority {
                    Some(p) => {
                        let ps = p.span();
                        let (p, pt) = self.check_expr(p)?;
                        if pt != Type::Int {
                            return CompileError::err(ps, "priority(expr) must be int");
                        }
                        Some(p)
                    }
                    None => None,
                };
                Ok(Stmt::Spawn {
                    queue,
                    priority,
                    dest,
                    call: CallExpr {
                        callee: call.callee,
                        args,
                        span: call.span,
                    },
                    span,
                })
            }
            Stmt::TaskWait { queue, span } => {
                if !self.is_task {
                    return CompileError::err(
                        span,
                        "#pragma gtap taskwait may only appear inside a #pragma gtap function",
                    );
                }
                if self.in_parfor > 0 {
                    return CompileError::err(
                        span,
                        "taskwait inside parallel_for: block-level taskwait must be \
                         reached by all threads along the same control flow (§5.1.3)",
                    );
                }
                let queue = match queue {
                    Some(q) => {
                        let qs = q.span();
                        let (q, qt) = self.check_expr(q)?;
                        if qt != Type::Int {
                            return CompileError::err(qs, "queue(expr) must be int");
                        }
                        Some(q)
                    }
                    None => None,
                };
                Ok(Stmt::TaskWait { queue, span })
            }
            Stmt::ParallelFor {
                var,
                lo,
                hi,
                body,
                span,
            } => {
                let (lo, lot) = self.check_expr(lo)?;
                let (hi, hit) = self.check_expr(hi)?;
                if lot != Type::Int || hit != Type::Int {
                    return CompileError::err(span, "parallel_for bounds must be int");
                }
                self.sc.push();
                let unique = self.sc.declare(&var, Type::Int, span)?;
                self.in_parfor += 1;
                let body = self.check_block(body, true)?;
                self.in_parfor -= 1;
                self.sc.pop();
                Ok(Stmt::ParallelFor {
                    var: unique,
                    lo,
                    hi,
                    body,
                    span,
                })
            }
            Stmt::Nested(b) => Ok(Stmt::Nested(self.check_block(b, false)?)),
        }
    }

    fn check_lvalue(&mut self, lv: LValue, span: Span) -> CompileResult<(LValue, Type)> {
        match lv {
            LValue::Var(name) => {
                if let Some(unique) = self.sc.resolve(&name) {
                    let ty = self.sc.type_of(unique);
                    Ok((LValue::Var(unique.to_string()), ty))
                } else if let Some(&ty) = self.an.globals.get(&name) {
                    Ok((LValue::Global(name), ty))
                } else {
                    CompileError::err(span, format!("unknown variable {name:?}"))
                }
            }
            LValue::Global(g) => {
                let ty = self.an.globals[&g];
                Ok((LValue::Global(g), ty))
            }
            LValue::Index { base, index } => {
                let (base, bt) = self.check_expr(base)?;
                if bt != Type::Ptr {
                    return CompileError::err(span, format!("indexed base must be ptr, got {bt}"));
                }
                let (index, it) = self.check_expr(index)?;
                if it != Type::Int {
                    return CompileError::err(span, "index must be int");
                }
                // memory is untyped words; stores take int (use float_to_bits
                // for floats)
                Ok((LValue::Index { base, index }, Type::Int))
            }
        }
    }

    fn check_cond(&mut self, e: Expr, span: Span) -> CompileResult<Expr> {
        let (e, ty) = self.check_expr(e)?;
        if ty != Type::Int {
            return CompileError::err(span, format!("condition must be int, got {ty}"));
        }
        Ok(e)
    }

    fn coerce(&self, e: Expr, from: Type, to: Type, span: Span) -> CompileResult<Expr> {
        if from == to {
            return Ok(e);
        }
        if from == Type::Int && to == Type::Float {
            return Ok(Expr::Cast {
                ty: Type::Float,
                expr: Box::new(e),
                span,
            });
        }
        CompileError::err(span, format!("type mismatch: expected {to}, got {from}"))
    }

    fn check_expr(&mut self, e: Expr) -> CompileResult<(Expr, Type)> {
        match e {
            Expr::IntLit(v) => Ok((Expr::IntLit(v), Type::Int)),
            Expr::FloatLit(v) => Ok((Expr::FloatLit(v), Type::Float)),
            Expr::Var(name, span) => {
                if let Some(unique) = self.sc.resolve(&name) {
                    let ty = self.sc.type_of(unique);
                    Ok((Expr::Var(unique.to_string(), span), ty))
                } else if let Some(&ty) = self.an.globals.get(&name) {
                    Ok((Expr::Global(name, span), ty))
                } else {
                    CompileError::err(span, format!("unknown variable {name:?}"))
                }
            }
            Expr::Global(name, span) => {
                let ty = self.an.globals[&name];
                Ok((Expr::Global(name, span), ty))
            }
            Expr::Unary { op, expr, span } => {
                let (expr, ty) = self.check_expr(*expr)?;
                let rty = match (op, ty) {
                    (UnOp::Neg, Type::Int) | (UnOp::Neg, Type::Float) => ty,
                    (UnOp::BitNot, Type::Int) => Type::Int,
                    (UnOp::Not, Type::Int) => Type::Int,
                    _ => {
                        return CompileError::err(
                            span,
                            format!("unary {op:?} not defined on {ty}"),
                        )
                    }
                };
                Ok((
                    Expr::Unary {
                        op,
                        expr: Box::new(expr),
                        span,
                    },
                    rty,
                ))
            }
            Expr::Binary { op, lhs, rhs, span } => {
                let (lhs, lt) = self.check_expr(*lhs)?;
                let (rhs, rt) = self.check_expr(*rhs)?;
                use BinOp::*;
                // ptr +/- int arithmetic
                if lt == Type::Ptr && rt == Type::Int && matches!(op, Add | Sub) {
                    return Ok((
                        Expr::Binary {
                            op,
                            lhs: Box::new(lhs),
                            rhs: Box::new(rhs),
                            span,
                        },
                        Type::Ptr,
                    ));
                }
                // int→float promotion
                let (lhs, rhs, ty) = if lt == rt {
                    (lhs, rhs, lt)
                } else if lt == Type::Int && rt == Type::Float {
                    (self.coerce(lhs, lt, Type::Float, span)?, rhs, Type::Float)
                } else if lt == Type::Float && rt == Type::Int {
                    (lhs, self.coerce(rhs, rt, Type::Float, span)?, Type::Float)
                } else {
                    return CompileError::err(
                        span,
                        format!("operands of {op:?} have incompatible types {lt} and {rt}"),
                    );
                };
                let rty = match op {
                    Add | Sub | Mul | Div => {
                        if ty == Type::Void {
                            return CompileError::err(span, "arithmetic on void");
                        }
                        ty
                    }
                    Rem | And | Or | Xor | Shl | Shr | LAnd | LOr => {
                        if ty != Type::Int {
                            return CompileError::err(
                                span,
                                format!("{op:?} requires int operands, got {ty}"),
                            );
                        }
                        Type::Int
                    }
                    Lt | Le | Gt | Ge | Eq | Ne => {
                        if ty == Type::Void {
                            return CompileError::err(span, "comparison on void");
                        }
                        Type::Int
                    }
                };
                Ok((
                    Expr::Binary {
                        op,
                        lhs: Box::new(lhs),
                        rhs: Box::new(rhs),
                        span,
                    },
                    rty,
                ))
            }
            Expr::Ternary {
                cond,
                then_e,
                else_e,
                span,
            } => {
                let cond = self.check_cond(*cond, span)?;
                let (then_e, tt) = self.check_expr(*then_e)?;
                let (else_e, et) = self.check_expr(*else_e)?;
                let (then_e, else_e, ty) = if tt == et {
                    (then_e, else_e, tt)
                } else if tt == Type::Int && et == Type::Float {
                    (
                        self.coerce(then_e, tt, Type::Float, span)?,
                        else_e,
                        Type::Float,
                    )
                } else if tt == Type::Float && et == Type::Int {
                    (
                        then_e,
                        self.coerce(else_e, et, Type::Float, span)?,
                        Type::Float,
                    )
                } else {
                    return CompileError::err(
                        span,
                        format!("ternary arms have incompatible types {tt} and {et}"),
                    );
                };
                Ok((
                    Expr::Ternary {
                        cond: Box::new(cond),
                        then_e: Box::new(then_e),
                        else_e: Box::new(else_e),
                        span,
                    },
                    ty,
                ))
            }
            Expr::Call(c) => {
                let span = c.span;
                // intrinsic?
                if let Some(sig) = intrinsics::lookup(&c.callee) {
                    if c.args.len() != sig.params.len() {
                        return CompileError::err(
                            span,
                            format!(
                                "intrinsic {:?} expects {} arguments, got {}",
                                c.callee,
                                sig.params.len(),
                                c.args.len()
                            ),
                        );
                    }
                    let mut args = Vec::new();
                    for (a, &pt) in c.args.into_iter().zip(sig.params) {
                        let sp = a.span();
                        let (a, aty) = self.check_expr(a)?;
                        args.push(self.coerce(a, aty, pt, sp)?);
                    }
                    return Ok((
                        Expr::Call(CallExpr {
                            callee: c.callee,
                            args,
                            span,
                        }),
                        sig.ret,
                    ));
                }
                // device function?
                let sig = self.an.fns.get(&c.callee).ok_or_else(|| {
                    CompileError::new(span, format!("unknown function {:?}", c.callee))
                })?;
                if sig.is_task {
                    return CompileError::err(
                        span,
                        format!(
                            "task function {:?} may only be invoked via #pragma gtap task",
                            c.callee
                        ),
                    );
                }
                if c.args.len() != sig.params.len() {
                    return CompileError::err(
                        span,
                        format!(
                            "{:?} expects {} arguments, got {}",
                            c.callee,
                            sig.params.len(),
                            c.args.len()
                        ),
                    );
                }
                let ret = sig.ret;
                let ptypes = sig.params.clone();
                let mut args = Vec::new();
                for (a, pt) in c.args.into_iter().zip(ptypes) {
                    let sp = a.span();
                    let (a, aty) = self.check_expr(a)?;
                    args.push(self.coerce(a, aty, pt, sp)?);
                }
                Ok((
                    Expr::Call(CallExpr {
                        callee: c.callee,
                        args,
                        span,
                    }),
                    ret,
                ))
            }
            Expr::Index { base, index, span } => {
                let (base, bt) = self.check_expr(*base)?;
                if bt != Type::Ptr {
                    return CompileError::err(span, format!("indexed base must be ptr, got {bt}"));
                }
                let (index, it) = self.check_expr(*index)?;
                if it != Type::Int {
                    return CompileError::err(span, "index must be int");
                }
                Ok((
                    Expr::Index {
                        base: Box::new(base),
                        index: Box::new(index),
                        span,
                    },
                    Type::Int,
                ))
            }
            Expr::Cast { ty, expr, span } => {
                let (expr, from) = self.check_expr(*expr)?;
                let ok = matches!(
                    (from, ty),
                    (Type::Int, Type::Float)
                        | (Type::Float, Type::Int)
                        | (Type::Int, Type::Ptr)
                        | (Type::Ptr, Type::Int)
                        | (Type::Int, Type::Int)
                        | (Type::Float, Type::Float)
                        | (Type::Ptr, Type::Ptr)
                );
                if !ok {
                    return CompileError::err(span, format!("invalid cast {from} -> {ty}"));
                }
                Ok((
                    Expr::Cast {
                        ty,
                        expr: Box::new(expr),
                        span,
                    },
                    ty,
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{lex::lex, parse::parse};

    fn check(src: &str) -> CompileResult<CheckedProgram> {
        analyze(parse(&lex(src).unwrap())?)
    }

    const FIB: &str = r#"
        #pragma gtap function
        int fib(int n) {
            if (n < 2) return n;
            int a; int b;
            #pragma gtap task
            a = fib(n - 1);
            #pragma gtap task
            b = fib(n - 2);
            #pragma gtap taskwait
            return a + b;
        }
    "#;

    #[test]
    fn fib_passes() {
        let p = check(FIB).unwrap();
        assert_eq!(p.tasks.len(), 1);
        assert_eq!(p.tasks[0].var_types["a"], Type::Int);
    }

    #[test]
    fn shadowing_renames() {
        let p = check(
            "#pragma gtap function\nvoid f(int n) { int x = 1; { int x = 2; n = x; } }",
        )
        .unwrap();
        let vt = &p.tasks[0].var_types;
        assert!(vt.contains_key("x"));
        assert!(vt.contains_key("x@2"));
    }

    #[test]
    fn unknown_variable_rejected() {
        let e = check("#pragma gtap function\nvoid f() { int x = y; }").unwrap_err();
        assert!(e.message.contains("unknown variable"), "{e}");
    }

    #[test]
    fn task_called_directly_rejected() {
        let e = check(
            "#pragma gtap function\nint t() { return 1; }\n\
             #pragma gtap function\nvoid f() { int x = t(); }",
        )
        .unwrap_err();
        assert!(e.message.contains("#pragma gtap task"), "{e}");
    }

    #[test]
    fn spawning_non_task_rejected() {
        let e = check(
            "int h() { return 1; }\n#pragma gtap function\nvoid f() {\n\
             #pragma gtap task\nh();\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("not a task function"), "{e}");
    }

    #[test]
    fn capture_without_taskwait_rejected() {
        let e = check(
            "#pragma gtap function\nint t(int n) { return n; }\n\
             #pragma gtap function\nvoid f() { int a;\n#pragma gtap task\na = t(1);\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("never joined"), "{e}");
    }

    #[test]
    fn control_flow_between_capture_and_join_rejected() {
        let e = check(
            "#pragma gtap function\nint t(int n) { return n; }\n\
             #pragma gtap function\nvoid f(int c) { int a;\n#pragma gtap task\na = t(1);\n\
             if (c) { c = 0; }\n#pragma gtap taskwait\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("straight-line"), "{e}");
    }

    #[test]
    fn void_capture_rejected() {
        let e = check(
            "#pragma gtap function\nvoid t() { return; }\n\
             #pragma gtap function\nvoid f() { int a;\n#pragma gtap task\na = t();\n\
             #pragma gtap taskwait\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("void"), "{e}");
    }

    #[test]
    fn taskwait_inside_parfor_rejected() {
        let e = check(
            "#pragma gtap function\nvoid f(int n) { parallel_for (i in 0..n) {\n\
             #pragma gtap taskwait\n} }",
        )
        .unwrap_err();
        assert!(e.message.contains("parallel_for"), "{e}");
    }

    #[test]
    fn spawn_inside_parfor_allowed() {
        check(
            "#pragma gtap function\nvoid bfs(int v) { parallel_for (i in 0..v) {\n\
             if (i > 1) {\n#pragma gtap task\nbfs(i);\n}\n} }",
        )
        .unwrap();
    }

    #[test]
    fn int_to_float_promotion() {
        let p = check("#pragma gtap function\nfloat f(int n) { return n + 0.5; }").unwrap();
        match &p.tasks[0].func.body.stmts[0] {
            Stmt::Return {
                value: Some(Expr::Binary { lhs, .. }),
                ..
            } => assert!(matches!(&**lhs, Expr::Cast { ty: Type::Float, .. })),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn float_bitops_rejected() {
        let e = check("#pragma gtap function\nfloat f(float x) { return x & x; }").unwrap_err();
        assert!(e.message.contains("requires int"), "{e}");
    }

    #[test]
    fn device_helper_shape_enforced() {
        // OK: decls + single return
        check("int half(int x) { int h = x / 2; return h; }").unwrap();
        // Bad: control flow in device fn
        let e = check("int bad(int x) { if (x) { return 1; } return 0; }").unwrap_err();
        assert!(e.message.contains("device function"), "{e}");
    }

    #[test]
    fn recursive_device_fn_rejected() {
        let e = check("int r(int x) { return r(x - 1); }").unwrap_err();
        assert!(e.message.contains("recursive"), "{e}");
    }

    #[test]
    fn mutually_recursive_device_fns_rejected() {
        let e = check(
            "int a(int x) { return b(x); }\nint b(int x) { return a(x); }",
        )
        .unwrap_err();
        assert!(e.message.contains("recursive"), "{e}");
    }

    #[test]
    fn intrinsic_shadowing_rejected() {
        let e = check("int payload(int x) { return x; }").unwrap_err();
        assert!(e.message.contains("intrinsic"), "{e}");
    }

    #[test]
    fn intrinsic_arity_checked() {
        let e = check("#pragma gtap function\nvoid f() { int x = fib_serial(); }").unwrap_err();
        assert!(e.message.contains("expects 1"), "{e}");
    }

    #[test]
    fn globals_resolve() {
        let p = check(
            "global int d_result;\n#pragma gtap function\nvoid f(int n) { d_result = n; }",
        )
        .unwrap();
        match &p.tasks[0].func.body.stmts[0] {
            Stmt::Assign {
                target: LValue::Global(g),
                ..
            } => assert_eq!(g, "d_result"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn queue_must_be_int() {
        let e = check(
            "#pragma gtap function\nvoid t() { return; }\n\
             #pragma gtap function\nvoid f() {\n#pragma gtap task queue(1.5)\nt();\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("queue"), "{e}");
    }

    #[test]
    fn priority_must_be_int() {
        let e = check(
            "#pragma gtap function\nvoid t() { return; }\n\
             #pragma gtap function\nvoid f() {\n#pragma gtap task priority(0.5)\nt();\n}",
        )
        .unwrap_err();
        assert!(e.message.contains("priority"), "{e}");
    }

    #[test]
    fn taskwait_outside_task_fn_rejected() {
        let e = check("void f() {\n#pragma gtap taskwait\n}").unwrap_err();
        assert!(e.message.contains("gtap function"), "{e}");
    }

    #[test]
    fn too_many_task_params_rejected() {
        let params: Vec<String> = (0..9).map(|i| format!("int p{i}")).collect();
        let src = format!(
            "#pragma gtap function\nvoid big({}) {{ return; }}",
            params.join(", ")
        );
        let e = check(&src).unwrap_err();
        assert!(e.message.contains("at most 8"), "{e}");
        // non-task device helpers are not limited
        let src_dev = format!("int f({}) {{ return p0; }}", params.join(", "));
        check(&src_dev).unwrap();
    }

    #[test]
    fn ptr_arithmetic() {
        check("#pragma gtap function\nvoid f(ptr p, int i) { p[0] = p[i]; ptr q = p + 4; p = q; }")
            .unwrap();
    }
}
