//! Compiler diagnostics.

use crate::ir::ast::Span;
use std::fmt;

/// A compile-time error with source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompileError {
    pub span: Span,
    pub message: String,
}

pub type CompileResult<T> = Result<T, CompileError>;

impl CompileError {
    pub fn new(span: Span, message: impl Into<String>) -> CompileError {
        CompileError {
            span,
            message: message.into(),
        }
    }

    /// Helper returning `Err` directly.
    pub fn err<T>(span: Span, message: impl Into<String>) -> CompileResult<T> {
        Err(Self::new(span, message))
    }
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gtapc error at {}: {}", self.span, self.message)
    }
}

impl std::error::Error for CompileError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_location() {
        let e = CompileError::new(Span { line: 4, col: 9 }, "bad thing");
        assert_eq!(e.to_string(), "gtapc error at 4:9: bad thing");
    }
}
