//! Bytecode generation with state-machine conversion (§5.2.2–5.2.3).
//!
//! Each task function becomes one [`FuncCode`] whose `state_entries` table
//! is the paper's `switch (state)`: entry 0 is the function start; the k-th
//! `taskwait` compiles to *evaluate queue expr* → [`Insn::PrepareJoin`]
//! (suspend) and registers `state_entries[k]` as the re-entry pc, where the
//! capture destinations are materialized from the child records
//! ([`Insn::ChildResult`], the analogue of `__gtap_load_result` in
//! Program 6). Every `return` is normalized to *store result field* →
//! [`Insn::FinishTask`], and a `FinishTask` is appended at the body end —
//! exactly the paper's rewrite of `return` into `__gtap_finish_task(...)`.
//!
//! Variables in the spill set (computed by [`super::liveness`]) are accessed
//! via task-data loads/stores; everything else lives in per-lane virtual
//! registers. Parameters are always task-data fields because GTaP copies
//! arguments at spawn time (firstprivate semantics, §5.1.2).
//!
//! Non-task device helpers are expanded inline at their call sites (their
//! restricted single-return shape was validated by sema).

use super::diag::{CompileError, CompileResult};
use super::liveness::analyze_spills;
use super::sema::{CheckedProgram, TypedFunction};
use crate::ir::ast::*;
use crate::ir::bytecode::*;
use crate::ir::intrinsics;
use crate::ir::layout::{FieldKind, TaskDataLayout};
use crate::ir::types::Type;
use std::collections::HashMap;

/// Generate a bytecode [`Module`] from a checked program.
pub fn generate(checked: &CheckedProgram, max_td_bytes: usize) -> CompileResult<Module> {
    let func_ids: HashMap<String, FuncId> = checked
        .tasks
        .iter()
        .enumerate()
        .map(|(i, t)| (t.func.name.clone(), i as FuncId))
        .collect();
    let global_addrs: HashMap<String, u64> = checked
        .globals
        .iter()
        .enumerate()
        .map(|(i, g)| (g.name.clone(), i as u64))
        .collect();

    let mut funcs = Vec::new();
    for tf in &checked.tasks {
        let mut cg = Codegen::new(tf, checked, &func_ids, &global_addrs)?;
        cg.run()?;
        let code = cg.finish();
        if code.layout.bytes() > max_td_bytes {
            return CompileError::err(
                tf.func.span,
                format!(
                    "task-data record of {:?} is {} bytes, exceeding \
                     GTAP_MAX_TASK_DATA_SIZE={max_td_bytes} (Table 1)",
                    tf.func.name,
                    code.layout.bytes()
                ),
            );
        }
        funcs.push(code);
    }
    Ok(Module {
        funcs,
        globals: checked
            .globals
            .iter()
            .map(|g| (g.name.clone(), g.ty))
            .collect(),
    })
}

/// Where a variable lives.
#[derive(Clone, Copy, Debug)]
enum Binding {
    Reg(Reg),
    Td(u16),
}

struct Codegen<'a> {
    tf: &'a TypedFunction,
    prog: &'a CheckedProgram,
    func_ids: &'a HashMap<String, FuncId>,
    global_addrs: &'a HashMap<String, u64>,

    insns: Vec<Insn>,
    arg_pool: Vec<Reg>,
    state_entries: Vec<Pc>,
    layout: TaskDataLayout,
    bindings: HashMap<String, Binding>,
    /// Types of inline-expansion temporaries (device-fn params/locals).
    inline_types: HashMap<String, Type>,

    next_reg: u16,
    max_reg: u16,
    /// Temp stack pointer (temps allocated above named registers).
    temp_base: u16,

    /// Captures awaiting the next taskwait: (dest var, child slot).
    pending_captures: Vec<(String, u16)>,
    /// Children spawned since the last taskwait (static count).
    spawns_in_region: u16,
    max_children_hint: u16,
    /// Loop nesting depth (spawn inside a loop ⇒ unbounded children hint).
    loop_depth: u32,
    has_taskwait: bool,
    uses_parfor: bool,
}

impl<'a> Codegen<'a> {
    fn new(
        tf: &'a TypedFunction,
        prog: &'a CheckedProgram,
        func_ids: &'a HashMap<String, FuncId>,
        global_addrs: &'a HashMap<String, u64>,
    ) -> CompileResult<Codegen<'a>> {
        let spills = analyze_spills(&tf.func);
        let mut layout = TaskDataLayout::default();
        let mut bindings = HashMap::new();
        // (i) original arguments
        for p in &tf.func.params {
            let off = layout.push(&p.name, p.ty, FieldKind::Arg);
            bindings.insert(p.name.clone(), Binding::Td(off));
        }
        // (ii) spilled locals (deterministic order: sort by name)
        let mut spill_names: Vec<&String> = spills.spilled.iter().collect();
        spill_names.sort();
        for name in spill_names {
            let ty = tf.var_types[name];
            let off = layout.push(name, ty, FieldKind::Spill);
            bindings.insert(name.clone(), Binding::Td(off));
        }
        // (iii) result field
        if tf.func.ret != Type::Void {
            layout.push("__result", tf.func.ret, FieldKind::Result);
        }
        // register-resident locals
        let mut next_reg: u16 = 0;
        let mut names: Vec<&String> = tf.var_types.keys().collect();
        names.sort();
        for name in names {
            if !bindings.contains_key(name.as_str()) {
                bindings.insert(name.clone(), Binding::Reg(next_reg));
                next_reg += 1;
            }
        }
        Ok(Codegen {
            tf,
            prog,
            func_ids,
            global_addrs,
            insns: vec![],
            arg_pool: vec![],
            state_entries: vec![0],
            layout,
            bindings,
            inline_types: HashMap::new(),
            temp_base: next_reg,
            next_reg,
            max_reg: next_reg,
            pending_captures: vec![],
            spawns_in_region: 0,
            max_children_hint: 0,
            loop_depth: 0,
            has_taskwait: spills.num_taskwaits > 0,
            uses_parfor: false,
        })
    }

    fn run(&mut self) -> CompileResult<()> {
        let body = self.tf.func.body.clone();
        self.gen_block(&body)?;
        // normalize: implicit finish at the end of the body
        self.emit(Insn::FinishTask);
        Ok(())
    }

    fn finish(self) -> FuncCode {
        FuncCode {
            name: self.tf.func.name.clone(),
            insns: self.insns,
            arg_pool: self.arg_pool,
            state_entries: self.state_entries,
            nregs: self.max_reg,
            layout: self.layout,
            max_children_hint: self.max_children_hint,
            has_taskwait: self.has_taskwait,
            uses_parfor: self.uses_parfor,
            ret: self.tf.func.ret,
        }
    }

    // ---- emission helpers -------------------------------------------------

    fn emit(&mut self, i: Insn) -> Pc {
        self.insns.push(i);
        (self.insns.len() - 1) as Pc
    }

    fn here(&self) -> Pc {
        self.insns.len() as Pc
    }

    fn temp(&mut self) -> Reg {
        let r = self.next_reg;
        self.next_reg += 1;
        if self.next_reg > self.max_reg {
            self.max_reg = self.next_reg;
        }
        r
    }

    /// Release temps back to `mark` (stack discipline per statement).
    fn release_temps(&mut self, mark: u16) {
        debug_assert!(mark >= self.temp_base);
        self.next_reg = mark;
    }

    fn temp_mark(&self) -> u16 {
        self.next_reg
    }

    fn const_to(&mut self, val: u64) -> Reg {
        let r = self.temp();
        self.emit(Insn::Const { dst: r, val });
        r
    }

    fn patch_jmp(&mut self, at: Pc, target: Pc) {
        match &mut self.insns[at as usize] {
            Insn::Jmp { target: t } => *t = target,
            other => panic!("patch_jmp on {other:?}"),
        }
    }

    fn patch_br(&mut self, at: Pc, t: Option<Pc>, f: Option<Pc>) {
        match &mut self.insns[at as usize] {
            Insn::Br { t: bt, f: bf, .. } => {
                if let Some(t) = t {
                    *bt = t;
                }
                if let Some(f) = f {
                    *bf = f;
                }
            }
            other => panic!("patch_br on {other:?}"),
        }
    }

    // ---- types ------------------------------------------------------------

    fn var_type(&self, name: &str) -> Type {
        if let Some(&t) = self.inline_types.get(name) {
            return t;
        }
        self.tf.var_types[name]
    }

    fn type_of(&self, e: &Expr) -> Type {
        match e {
            Expr::IntLit(_) => Type::Int,
            Expr::FloatLit(_) => Type::Float,
            Expr::Var(n, _) => self.var_type(n),
            Expr::Global(g, _) => {
                self.prog
                    .globals
                    .iter()
                    .find(|d| &d.name == g)
                    .expect("sema resolved global")
                    .ty
            }
            Expr::Unary { op, expr, .. } => match op {
                UnOp::Not => Type::Int,
                _ => self.type_of(expr),
            },
            Expr::Binary { op, lhs, rhs, .. } => {
                use BinOp::*;
                match op {
                    Lt | Le | Gt | Ge | Eq | Ne | LAnd | LOr | Rem | And | Or | Xor | Shl
                    | Shr => Type::Int,
                    Add | Sub | Mul | Div => {
                        let lt = self.type_of(lhs);
                        if lt == Type::Ptr {
                            Type::Ptr
                        } else if lt == Type::Float || self.type_of(rhs) == Type::Float {
                            Type::Float
                        } else {
                            Type::Int
                        }
                    }
                }
            }
            Expr::Ternary { then_e, .. } => self.type_of(then_e),
            Expr::Call(c) => {
                if let Some(sig) = intrinsics::lookup(&c.callee) {
                    sig.ret
                } else {
                    self.prog.devices[&c.callee].func.ret
                }
            }
            Expr::Index { .. } => Type::Int,
            Expr::Cast { ty, .. } => *ty,
        }
    }

    // ---- statements ---------------------------------------------------------

    fn gen_block(&mut self, b: &Block) -> CompileResult<()> {
        for s in &b.stmts {
            self.gen_stmt(s)?;
        }
        Ok(())
    }

    fn store_var(&mut self, name: &str, src: Reg) {
        match self.bindings[name] {
            Binding::Reg(r) => {
                self.emit(Insn::Mov { dst: r, src });
            }
            Binding::Td(off) => {
                self.emit(Insn::StTd { off, src });
            }
        }
    }

    fn gen_stmt(&mut self, s: &Stmt) -> CompileResult<()> {
        let mark = self.temp_mark();
        match s {
            Stmt::Decl { name, init, .. } => {
                if let Some(e) = init {
                    let r = self.gen_expr(e)?;
                    self.store_var(name, r);
                }
            }
            Stmt::Assign { target, value, .. } => {
                let v = self.gen_expr(value)?;
                match target {
                    LValue::Var(name) => self.store_var(name, v),
                    LValue::Global(g) => {
                        let addr = self.const_to(self.global_addrs[g]);
                        self.emit(Insn::StG {
                            addr,
                            src: v,
                            cache: CacheOp::Cg,
                        });
                    }
                    LValue::Index { base, index } => {
                        let b = self.gen_expr(base)?;
                        let i = self.gen_expr(index)?;
                        let addr = self.temp();
                        self.emit(Insn::Bin {
                            op: BinKind::IAdd,
                            dst: addr,
                            a: b,
                            b: i,
                        });
                        self.emit(Insn::StG {
                            addr,
                            src: v,
                            cache: CacheOp::Ca,
                        });
                    }
                }
            }
            Stmt::ExprStmt { expr, .. } => {
                self.gen_expr(expr)?;
            }
            Stmt::Return { value, .. } => {
                if let Some(e) = value {
                    let r = self.gen_expr(e)?;
                    let off = self.layout.result_offset().expect("result field");
                    self.emit(Insn::StTd { off, src: r });
                }
                self.emit(Insn::FinishTask);
            }
            Stmt::Spawn {
                queue,
                priority,
                dest,
                call,
                ..
            } => {
                // evaluate args into a contiguous arg-pool run
                let mut arg_regs = Vec::with_capacity(call.args.len());
                for a in &call.args {
                    arg_regs.push(self.gen_expr(a)?);
                }
                let queue_reg = match queue {
                    Some(q) => self.gen_expr(q)?,
                    None => self.const_to(0),
                };
                // absent priority emits no code at all: the sentinel tells
                // the runtime to inherit the parent's priority
                let priority_reg = match priority {
                    Some(p) => self.gen_expr(p)?,
                    None => NO_PRIORITY_REG,
                };
                let arg_base = self.arg_pool.len() as u32;
                self.arg_pool.extend_from_slice(&arg_regs);
                let func = self.func_ids[&call.callee];
                self.emit(Insn::Spawn {
                    func,
                    arg_base,
                    argc: arg_regs.len() as u8,
                    queue: queue_reg,
                    priority: priority_reg,
                });
                if let Some(d) = dest {
                    self.pending_captures
                        .push((d.clone(), self.spawns_in_region));
                }
                self.spawns_in_region = self.spawns_in_region.saturating_add(1);
                if self.loop_depth > 0 {
                    self.max_children_hint = u16::MAX;
                } else {
                    self.max_children_hint =
                        self.max_children_hint.max(self.spawns_in_region);
                }
            }
            Stmt::TaskWait { queue, .. } => {
                let queue_reg = match queue {
                    Some(q) => self.gen_expr(q)?,
                    None => self.const_to(0),
                };
                let next_state = self.state_entries.len() as u16;
                self.emit(Insn::PrepareJoin {
                    next_state,
                    queue: queue_reg,
                });
                // --- state boundary: re-entry point ---
                self.release_temps(mark);
                let entry = self.here();
                self.state_entries.push(entry);
                // materialize capture destinations from child records
                let captures = std::mem::take(&mut self.pending_captures);
                for (dest, slot) in captures {
                    let r = self.temp();
                    self.emit(Insn::ChildResult { dst: r, slot });
                    self.store_var(&dest, r);
                }
                self.spawns_in_region = 0;
            }
            Stmt::If {
                cond,
                then_blk,
                else_blk,
                ..
            } => {
                let c = self.gen_expr(cond)?;
                let br = self.emit(Insn::Br { cond: c, t: 0, f: 0 });
                let then_pc = self.here();
                self.gen_block(then_blk)?;
                match else_blk {
                    Some(e) => {
                        let jmp_end = self.emit(Insn::Jmp { target: 0 });
                        let else_pc = self.here();
                        self.gen_block(e)?;
                        let end = self.here();
                        self.patch_br(br, Some(then_pc), Some(else_pc));
                        self.patch_jmp(jmp_end, end);
                    }
                    None => {
                        let end = self.here();
                        self.patch_br(br, Some(then_pc), Some(end));
                    }
                }
            }
            Stmt::While { cond, body, .. } => {
                let cond_pc = self.here();
                let c = self.gen_expr(cond)?;
                let br = self.emit(Insn::Br { cond: c, t: 0, f: 0 });
                let body_pc = self.here();
                self.loop_depth += 1;
                self.gen_block(body)?;
                self.loop_depth -= 1;
                self.emit(Insn::Jmp { target: cond_pc });
                let end = self.here();
                self.patch_br(br, Some(body_pc), Some(end));
            }
            Stmt::For {
                init,
                cond,
                step,
                body,
                ..
            } => {
                if let Some(i) = init {
                    self.gen_stmt(i)?;
                }
                let cond_pc = self.here();
                let br = match cond {
                    Some(c) => {
                        let r = self.gen_expr(c)?;
                        Some(self.emit(Insn::Br { cond: r, t: 0, f: 0 }))
                    }
                    None => None,
                };
                let body_pc = self.here();
                self.loop_depth += 1;
                self.gen_block(body)?;
                if let Some(st) = step {
                    self.gen_stmt(st)?;
                }
                self.loop_depth -= 1;
                self.emit(Insn::Jmp { target: cond_pc });
                let end = self.here();
                if let Some(br) = br {
                    self.patch_br(br, Some(body_pc), Some(end));
                }
            }
            Stmt::ParallelFor {
                var, lo, hi, body, ..
            } => {
                self.uses_parfor = true;
                let lo_r = self.gen_expr(lo)?;
                let hi_r = self.gen_expr(hi)?;
                // keep hi in a dedicated temp that survives the loop
                let trips = self.temp();
                self.emit(Insn::Bin {
                    op: BinKind::ISub,
                    dst: trips,
                    a: hi_r,
                    b: lo_r,
                });
                self.emit(Insn::ParEnter { trips });
                // induction var is a named binding (register — parallel_for
                // cannot contain taskwait, so never spilled)
                self.store_var(var, lo_r);
                let var_reg = match self.bindings[var.as_str()] {
                    Binding::Reg(r) => r,
                    Binding::Td(_) => unreachable!("parfor var cannot be spilled"),
                };
                let cond_pc = self.here();
                let c = self.temp();
                self.emit(Insn::Bin {
                    op: BinKind::ILt,
                    dst: c,
                    a: var_reg,
                    b: hi_r,
                });
                let br = self.emit(Insn::Br { cond: c, t: 0, f: 0 });
                let body_pc = self.here();
                self.loop_depth += 1;
                self.gen_block(body)?;
                self.loop_depth -= 1;
                let one = self.const_to(1);
                self.emit(Insn::Bin {
                    op: BinKind::IAdd,
                    dst: var_reg,
                    a: var_reg,
                    b: one,
                });
                self.emit(Insn::Jmp { target: cond_pc });
                let end = self.here();
                self.patch_br(br, Some(body_pc), Some(end));
                self.emit(Insn::ParExit);
            }
            Stmt::Nested(b) => self.gen_block(b)?,
        }
        // statement boundary: recycle expression temps (named regs persist)
        if !matches!(s, Stmt::TaskWait { .. }) {
            self.release_temps(mark);
        }
        Ok(())
    }

    // ---- expressions --------------------------------------------------------

    fn gen_expr(&mut self, e: &Expr) -> CompileResult<Reg> {
        match e {
            Expr::IntLit(v) => Ok(self.const_to(*v as u64)),
            Expr::FloatLit(v) => Ok(self.const_to(v.to_bits())),
            Expr::Var(name, _) => match self.bindings[name.as_str()] {
                Binding::Reg(r) => Ok(r),
                Binding::Td(off) => {
                    let dst = self.temp();
                    self.emit(Insn::LdTd { dst, off });
                    Ok(dst)
                }
            },
            Expr::Global(g, _) => {
                let addr = self.const_to(self.global_addrs[g]);
                let dst = self.temp();
                self.emit(Insn::LdG {
                    dst,
                    addr,
                    cache: CacheOp::Cg,
                });
                Ok(dst)
            }
            Expr::Unary { op, expr, .. } => {
                let a = self.gen_expr(expr)?;
                let ty = self.type_of(expr);
                let dst = self.temp();
                let kind = match (op, ty) {
                    (UnOp::Neg, Type::Float) => UnKind::FNeg,
                    (UnOp::Neg, _) => UnKind::INeg,
                    (UnOp::BitNot, _) => UnKind::IBitNot,
                    (UnOp::Not, _) => UnKind::LNot,
                };
                self.emit(Insn::Un { op: kind, dst, a });
                Ok(dst)
            }
            Expr::Binary { op, lhs, rhs, .. } => {
                use BinOp::*;
                if matches!(op, LAnd | LOr) {
                    return self.gen_short_circuit(*op, lhs, rhs);
                }
                let a = self.gen_expr(lhs)?;
                let b = self.gen_expr(rhs)?;
                let f = self.type_of(lhs) == Type::Float || self.type_of(rhs) == Type::Float;
                let kind = match (op, f) {
                    (Add, false) => BinKind::IAdd,
                    (Sub, false) => BinKind::ISub,
                    (Mul, false) => BinKind::IMul,
                    (Div, false) => BinKind::IDiv,
                    (Rem, _) => BinKind::IRem,
                    (And, _) => BinKind::IAnd,
                    (Or, _) => BinKind::IOr,
                    (Xor, _) => BinKind::IXor,
                    (Shl, _) => BinKind::IShl,
                    (Shr, _) => BinKind::IShr,
                    (Lt, false) => BinKind::ILt,
                    (Le, false) => BinKind::ILe,
                    (Gt, false) => BinKind::IGt,
                    (Ge, false) => BinKind::IGe,
                    (Eq, false) => BinKind::IEq,
                    (Ne, false) => BinKind::INe,
                    (Add, true) => BinKind::FAdd,
                    (Sub, true) => BinKind::FSub,
                    (Mul, true) => BinKind::FMul,
                    (Div, true) => BinKind::FDiv,
                    (Lt, true) => BinKind::FLt,
                    (Le, true) => BinKind::FLe,
                    (Gt, true) => BinKind::FGt,
                    (Ge, true) => BinKind::FGe,
                    (Eq, true) => BinKind::FEq,
                    (Ne, true) => BinKind::FNe,
                    (LAnd, _) | (LOr, _) => unreachable!(),
                };
                let dst = self.temp();
                self.emit(Insn::Bin { op: kind, dst, a, b });
                Ok(dst)
            }
            Expr::Ternary {
                cond,
                then_e,
                else_e,
                ..
            } => {
                let dst = self.temp();
                let c = self.gen_expr(cond)?;
                let br = self.emit(Insn::Br { cond: c, t: 0, f: 0 });
                let then_pc = self.here();
                let tr = self.gen_expr(then_e)?;
                self.emit(Insn::Mov { dst, src: tr });
                let jmp = self.emit(Insn::Jmp { target: 0 });
                let else_pc = self.here();
                let er = self.gen_expr(else_e)?;
                self.emit(Insn::Mov { dst, src: er });
                let end = self.here();
                self.patch_br(br, Some(then_pc), Some(else_pc));
                self.patch_jmp(jmp, end);
                Ok(dst)
            }
            Expr::Call(c) => self.gen_call(c),
            Expr::Index { base, index, .. } => {
                let b = self.gen_expr(base)?;
                let i = self.gen_expr(index)?;
                let addr = self.temp();
                self.emit(Insn::Bin {
                    op: BinKind::IAdd,
                    dst: addr,
                    a: b,
                    b: i,
                });
                let dst = self.temp();
                self.emit(Insn::LdG {
                    dst,
                    addr,
                    cache: CacheOp::Ca,
                });
                Ok(dst)
            }
            Expr::Cast { ty, expr, .. } => {
                let from = self.type_of(expr);
                let a = self.gen_expr(expr)?;
                match (from, ty) {
                    (Type::Int, Type::Float) => {
                        let dst = self.temp();
                        self.emit(Insn::Un {
                            op: UnKind::IToF,
                            dst,
                            a,
                        });
                        Ok(dst)
                    }
                    (Type::Float, Type::Int) => {
                        let dst = self.temp();
                        self.emit(Insn::Un {
                            op: UnKind::FToI,
                            dst,
                            a,
                        });
                        Ok(dst)
                    }
                    // reinterpreting int<->ptr / identity casts are free
                    _ => Ok(a),
                }
            }
        }
    }

    fn gen_short_circuit(&mut self, op: BinOp, lhs: &Expr, rhs: &Expr) -> CompileResult<Reg> {
        let dst = self.temp();
        let a = self.gen_expr(lhs)?;
        let br = self.emit(Insn::Br { cond: a, t: 0, f: 0 });
        let zero = self.const_to(0);
        let norm = |cg: &mut Self, val: Reg, zero: Reg, dst: Reg| {
            cg.emit(Insn::Bin {
                op: BinKind::INe,
                dst,
                a: val,
                b: zero,
            });
        };
        match op {
            BinOp::LAnd => {
                // lhs true -> dst = (rhs != 0); lhs false -> dst = 0
                let rhs_pc = self.here();
                let b = self.gen_expr(rhs)?;
                norm(self, b, zero, dst);
                let jmp = self.emit(Insn::Jmp { target: 0 });
                let false_pc = self.here();
                self.emit(Insn::Const { dst, val: 0 });
                let end = self.here();
                self.patch_br(br, Some(rhs_pc), Some(false_pc));
                self.patch_jmp(jmp, end);
            }
            BinOp::LOr => {
                // lhs true -> dst = 1; lhs false -> dst = (rhs != 0)
                let true_pc = self.here();
                self.emit(Insn::Const { dst, val: 1 });
                let jmp = self.emit(Insn::Jmp { target: 0 });
                let rhs_pc = self.here();
                let b = self.gen_expr(rhs)?;
                norm(self, b, zero, dst);
                let end = self.here();
                self.patch_br(br, Some(true_pc), Some(rhs_pc));
                self.patch_jmp(jmp, end);
            }
            _ => unreachable!(),
        }
        Ok(dst)
    }

    fn gen_call(&mut self, c: &CallExpr) -> CompileResult<Reg> {
        // intrinsic?
        if let Some(sig) = intrinsics::lookup(&c.callee) {
            let mut arg_regs = Vec::with_capacity(c.args.len());
            for a in &c.args {
                arg_regs.push(self.gen_expr(a)?);
            }
            let arg_base = self.arg_pool.len() as u32;
            self.arg_pool.extend_from_slice(&arg_regs);
            let has_dst = sig.ret != Type::Void;
            let dst = if has_dst { self.temp() } else { 0 };
            self.emit(Insn::Intr {
                id: sig.id,
                dst,
                arg_base,
                argc: arg_regs.len() as u8,
                has_dst,
            });
            return Ok(dst);
        }
        // device helper: inline expansion
        self.inline_device(c)
    }

    /// Inline a device helper: bind params to evaluated argument registers,
    /// emit its decls, then its return expression. Sema guarantees the
    /// restricted shape and acyclicity.
    fn inline_device(&mut self, c: &CallExpr) -> CompileResult<Reg> {
        let dev = self.prog.devices[&c.callee].clone();
        // Names the expansion introduces: params + all locals. Device
        // functions were alpha-renamed independently, so a local may collide
        // with a caller variable — save and restore every introduced name.
        let mut introduced: Vec<String> =
            dev.func.params.iter().map(|p| p.name.clone()).collect();
        for s in &dev.func.body.stmts {
            if let Stmt::Decl { name, .. } = s {
                introduced.push(name.clone());
            }
        }
        let saved: Vec<(String, Option<Binding>, Option<Type>)> = introduced
            .iter()
            .map(|k| {
                (
                    k.clone(),
                    self.bindings.get(k).copied(),
                    self.inline_types.get(k).copied(),
                )
            })
            .collect();

        // Evaluate arguments in the caller's frame, copying each into a
        // fresh temp so later argument evaluation cannot clobber it.
        for (a, p) in c.args.iter().zip(&dev.func.params) {
            let r = self.gen_expr(a)?;
            let t = self.temp();
            self.emit(Insn::Mov { dst: t, src: r });
            self.bindings.insert(p.name.clone(), Binding::Reg(t));
            self.inline_types.insert(p.name.clone(), p.ty);
        }

        let mut result: Reg = 0;
        for (i, s) in dev.func.body.stmts.iter().enumerate() {
            match s {
                Stmt::Decl {
                    name,
                    ty,
                    init: Some(e),
                    ..
                } => {
                    let r = self.gen_expr(e)?;
                    let t = self.temp();
                    self.emit(Insn::Mov { dst: t, src: r });
                    self.bindings.insert(name.clone(), Binding::Reg(t));
                    self.inline_types.insert(name.clone(), *ty);
                }
                Stmt::ExprStmt { expr, .. } => {
                    self.gen_expr(expr)?;
                }
                Stmt::Return { value, .. } => {
                    debug_assert_eq!(i + 1, dev.func.body.stmts.len());
                    if let Some(e) = value {
                        result = self.gen_expr(e)?;
                    }
                }
                _ => unreachable!("sema enforced device shape"),
            }
        }
        // Restore caller bindings shadowed by the expansion.
        for (k, old_b, old_t) in saved {
            match old_b {
                Some(b) => {
                    self.bindings.insert(k.clone(), b);
                }
                None => {
                    self.bindings.remove(&k);
                }
            }
            match old_t {
                Some(t) => {
                    self.inline_types.insert(k, t);
                }
                None => {
                    self.inline_types.remove(&k);
                }
            }
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile_default;

    const FIB: &str = r#"
        global int d_result;
        #pragma gtap function
        int fib(int n) {
            if (n < 2) return n;
            int a; int b;
            #pragma gtap task queue((n - 1) < 2 ? 1 : 0)
            a = fib(n - 1);
            #pragma gtap task queue((n - 2) < 2 ? 1 : 0)
            b = fib(n - 2);
            #pragma gtap taskwait queue(2)
            return a + b;
        }
    "#;

    #[test]
    fn fib_compiles_to_two_states() {
        let m = compile_default(FIB).unwrap();
        let f = m.func(m.func_id("fib").unwrap());
        assert_eq!(f.num_states(), 2, "entry + one taskwait re-entry");
        assert!(f.has_taskwait);
        assert_eq!(f.max_children_hint, 2);
        // layout == Program 6: n (arg), a, b (spills), __result
        assert_eq!(f.layout.words(), 4);
        assert_eq!(f.layout.offset_of("n"), Some(0));
        assert!(f.layout.offset_of("a").is_some());
        assert!(f.layout.offset_of("b").is_some());
        assert_eq!(f.layout.result_offset(), Some(3));
    }

    #[test]
    fn state1_loads_child_results() {
        let m = compile_default(FIB).unwrap();
        let f = m.func(0);
        let entry1 = f.state_entries[1] as usize;
        // the first instructions of state 1 materialize a and b
        let slots: Vec<u16> = f.insns[entry1..]
            .iter()
            .filter_map(|i| match i {
                Insn::ChildResult { slot, .. } => Some(*slot),
                _ => None,
            })
            .collect();
        assert_eq!(slots, vec![0, 1]);
    }

    #[test]
    fn spawns_carry_queue_exprs() {
        let m = compile_default(FIB).unwrap();
        let f = m.func(0);
        let spawns = f
            .insns
            .iter()
            .filter(|i| matches!(i, Insn::Spawn { .. }))
            .count();
        assert_eq!(spawns, 2);
        let joins = f
            .insns
            .iter()
            .filter(|i| matches!(i, Insn::PrepareJoin { next_state: 1, .. }))
            .count();
        assert_eq!(joins, 1);
    }

    #[test]
    fn spawns_carry_priority_exprs_or_the_inherit_sentinel() {
        // unannotated spawns carry the sentinel (inherit)
        let m = compile_default(FIB).unwrap();
        for i in &m.func(0).insns {
            if let Insn::Spawn { priority, .. } = i {
                assert_eq!(*priority, NO_PRIORITY_REG);
            }
        }
        // an annotated spawn evaluates its expression into a real register
        let src = r#"
            #pragma gtap function
            void walk(int d) {
                if (d > 0) {
                    #pragma gtap task priority(d - 1)
                    walk(d - 1);
                }
            }
        "#;
        let m = compile_default(src).unwrap();
        let prios: Vec<Reg> = m
            .func(0)
            .insns
            .iter()
            .filter_map(|i| match i {
                Insn::Spawn { priority, .. } => Some(*priority),
                _ => None,
            })
            .collect();
        assert_eq!(prios.len(), 1);
        assert_ne!(prios[0], NO_PRIORITY_REG);
    }

    #[test]
    fn returns_normalized_to_finish() {
        let m = compile_default(FIB).unwrap();
        let f = m.func(0);
        let finishes = f
            .insns
            .iter()
            .filter(|i| matches!(i, Insn::FinishTask))
            .count();
        // `return n`, `return a+b`, and the implicit end-of-body finish
        assert_eq!(finishes, 3);
    }

    #[test]
    fn no_taskwait_single_state() {
        let m = compile_default(
            "#pragma gtap function\nvoid leaf(int n) { print_int(n); }",
        )
        .unwrap();
        let f = m.func(0);
        assert_eq!(f.num_states(), 1);
        assert!(!f.has_taskwait);
        assert_eq!(f.layout.words(), 1); // just the arg
    }

    #[test]
    fn task_data_size_limit_enforced() {
        // 6 args + result = 56 bytes > 32-byte cap
        let params: Vec<String> = (0..6).map(|i| format!("int p{i}")).collect();
        let src = format!(
            "#pragma gtap function\nint big({}) {{ return p0; }}",
            params.join(", ")
        );
        let err = crate::compiler::compile(&src, 32).unwrap_err();
        assert!(err.message.contains("GTAP_MAX_TASK_DATA_SIZE"), "{err}");
    }

    #[test]
    fn spawn_in_loop_unbounded_hint() {
        let m = compile_default(
            "#pragma gtap function\nvoid c(int x) { print_int(x); }\n\
             #pragma gtap function\nvoid f(int n) {\n\
             int i = 0;\n\
             while (i < n) {\n#pragma gtap task\nc(i);\ni = i + 1; }\n\
             #pragma gtap taskwait\n}",
        )
        .unwrap();
        let f = m.func(m.func_id("f").unwrap());
        assert_eq!(f.max_children_hint, u16::MAX);
    }

    #[test]
    fn device_helper_inlined() {
        let m = compile_default(
            "int twice(int x) { return x * 2; }\n\
             #pragma gtap function\nint f(int n) { return twice(n) + 1; }",
        )
        .unwrap();
        // only the task function is materialized
        assert_eq!(m.funcs.len(), 1);
        let f = m.func(0);
        // the multiply from `twice` is inline
        assert!(f
            .insns
            .iter()
            .any(|i| matches!(i, Insn::Bin { op: BinKind::IMul, .. })));
    }

    #[test]
    fn parfor_emits_region_markers() {
        let m = compile_default(
            "#pragma gtap function\nvoid f(int n) { parallel_for (i in 0..n) { print_int(i); } }",
        )
        .unwrap();
        let f = m.func(0);
        assert!(f.uses_parfor);
        assert!(f.insns.iter().any(|i| matches!(i, Insn::ParEnter { .. })));
        assert!(f.insns.iter().any(|i| matches!(i, Insn::ParExit)));
    }

    #[test]
    fn globals_addressed_in_order() {
        let m = compile_default(
            "global int g0;\nglobal float g1;\n\
             #pragma gtap function\nvoid f() { g0 = 1; g1 = 2.0; }",
        )
        .unwrap();
        assert_eq!(m.global_addr("g0"), Some(0));
        assert_eq!(m.global_addr("g1"), Some(1));
        assert_eq!(m.globals_words(), 2);
    }

    #[test]
    fn short_circuit_branches() {
        let m = compile_default(
            "#pragma gtap function\nint f(int a, int b) { return a && b || !a; }",
        )
        .unwrap();
        let f = m.func(0);
        let brs = f.insns.iter().filter(|i| matches!(i, Insn::Br { .. })).count();
        assert!(brs >= 2, "short-circuit ops must lower to branches");
    }

    #[test]
    fn float_ops_selected() {
        let m = compile_default(
            "#pragma gtap function\nfloat f(float x) { return x * 2.0 + 1.0; }",
        )
        .unwrap();
        let f = m.func(0);
        assert!(f.insns.iter().any(|i| matches!(i, Insn::Bin { op: BinKind::FMul, .. })));
        assert!(f.insns.iter().any(|i| matches!(i, Insn::Bin { op: BinKind::FAdd, .. })));
    }

    #[test]
    fn cast_emits_conversion() {
        let m = compile_default(
            "#pragma gtap function\nint f(float x) { return (int) x; }",
        )
        .unwrap();
        assert!(m.func(0)
            .insns
            .iter()
            .any(|i| matches!(i, Insn::Un { op: UnKind::FToI, .. })));
    }
}
