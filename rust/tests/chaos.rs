//! Chaos differential suite for the fault plane (`--faults`).
//!
//! The contract under test (ARCHITECTURE.md "Fault model & recovery"):
//! under **any** deterministic fault plan the run terminates and its
//! workload *results* are bit-identical to the fault-free run — faults
//! may only remove or delay work, never execute a segment's effects
//! twice. Every runner used here validates its result against the native
//! reference internally (`ensure!`), so a chaos run that recovered
//! incorrectly fails its own measurement; on top of that the suite pins
//! result- and task-count equality against fault-free baselines, and the
//! faults-off case byte-identical against the pre-refactor monolith's
//! pinned stats (cost transparency).

use gtap::bench::runners::{self, Exec};
use gtap::compiler;
use gtap::coordinator::scheduler_ref::RefScheduler;
use gtap::coordinator::{
    FaultKind, FaultPlan, GtapConfig, Scheduler, SchedulerKind, Session, SmTier,
};
use gtap::ir::types::Value;
use gtap::ir::LoweredModule;
use gtap::runtime::service::{AdmissionPolicy, JobStatus, ServiceEngine, SubmitOpts};
use gtap::sim::profile::Profiler;
use gtap::sim::{DeviceSpec, Memory};
use gtap::workloads::fib;

fn no_faults(s: &gtap::coordinator::RunStats) {
    assert_eq!(s.faults_injected, 0);
    assert_eq!(s.workers_lost, 0);
    assert_eq!(s.tasks_reexecuted, 0);
    assert_eq!(s.watchdog_trips, 0);
    assert!(!s.drained);
}

#[test]
fn faults_off_is_byte_identical() {
    // An explicit "off" plan and the default plan take the identical code
    // path: full RunStats equality, including cycles.
    let base = runners::run_fib(&Exec::gpu_thread(4, 32), 13, 0, false).unwrap();
    let off = Exec::gpu_thread(4, 32).faults(FaultPlan::parse("off").unwrap());
    let explicit = runners::run_fib(&off, 13, 0, false).unwrap();
    assert_eq!(base.stats, explicit.stats);
    no_faults(&base.stats);
}

#[test]
fn faults_off_matches_reference_monolith() {
    // The hardened scheduler (watchdog armed, fault branches compiled in)
    // must stay byte-identical to the pre-refactor monolith, which knows
    // nothing about faults.
    let cfg = GtapConfig {
        grid_size: 2,
        block_size: 64,
        ..Default::default()
    };
    let dev = DeviceSpec::h100();
    let module = compiler::compile(&fib::source(0, false), cfg.max_task_data_size).unwrap();
    let lowered = LoweredModule::lower(module, &dev);
    let module = &lowered.module;
    let run_new = {
        let mut mem = Memory::new(module.globals_words());
        let mut prof = Profiler::disabled();
        let mut s = Scheduler::new(&lowered, &cfg, &dev).unwrap();
        s.spawn_root("fib", &[Value::from_i64(13)]).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    let run_ref = {
        let mut mem = Memory::new(module.globals_words());
        let mut prof = Profiler::disabled();
        let mut s = RefScheduler::new(module, &cfg, &dev).unwrap();
        s.spawn_root("fib", &[Value::from_i64(13)]).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    assert_eq!(run_new, run_ref);
}

#[test]
fn deterministic_kill_recovers_bit_identically() {
    let base = runners::run_fib(&Exec::gpu_thread(4, 32), 13, 0, false).unwrap();
    let e = Exec::gpu_thread(4, 32).faults(FaultPlan::parse("kill@0:w1").unwrap());
    let out = runners::run_fib(&e, 13, 0, false).unwrap();
    // run_fib validated fib(13) == 233 internally; pin the counters too
    assert_eq!(out.stats.workers_lost, 1);
    assert!(out.stats.faults_injected >= 1);
    assert_eq!(
        out.stats.tasks_finished, base.stats.tasks_finished,
        "every task must finish exactly once despite the kill"
    );
    assert_eq!(out.stats.root_result, base.stats.root_result);
    assert!(!out.stats.drained);
}

#[test]
fn kill_never_takes_the_last_worker() {
    // Both workers are targeted at t=0; the second kill must be skipped
    // (and stay uncounted) or the run could never finish.
    let e = Exec::gpu_thread(2, 32).faults(FaultPlan::parse("kill@0:w0;kill@0:w1").unwrap());
    let out = runners::run_fib(&e, 12, 0, false).unwrap();
    assert_eq!(out.stats.workers_lost, 1, "exactly one kill lands");
    assert_eq!(out.stats.faults_injected, 1);
}

#[test]
fn transient_stall_preserves_results() {
    let base = runners::run_fib(&Exec::gpu_thread(4, 32), 13, 0, false).unwrap();
    let e = Exec::gpu_thread(4, 32).faults(FaultPlan::parse("stall@0:w0:5000").unwrap());
    let out = runners::run_fib(&e, 13, 0, false).unwrap();
    assert_eq!(out.stats.faults_injected, 1);
    assert_eq!(out.stats.workers_lost, 0);
    assert_eq!(out.stats.tasks_finished, base.stats.tasks_finished);
    assert_eq!(out.stats.root_result, base.stats.root_result);
}

#[test]
fn steal_failure_storm_preserves_results() {
    let base = runners::run_fib(&Exec::gpu_thread(4, 32), 13, 0, false).unwrap();
    let e = Exec::gpu_thread(4, 32).faults(FaultPlan::parse("stealfail@0:w1:64").unwrap());
    let out = runners::run_fib(&e, 13, 0, false).unwrap();
    assert_eq!(out.stats.faults_injected, 1);
    assert_eq!(out.stats.tasks_finished, base.stats.tasks_finished);
    assert_eq!(out.stats.root_result, base.stats.root_result);
}

#[test]
fn dropped_entries_are_recovered_by_the_watchdog() {
    // Drops only land when the target queue is non-empty at delivery, so
    // schedule several and branch on what actually vanished: every
    // delivered drop loses a task the watchdog must find and re-enqueue.
    let base = runners::run_fib(&Exec::gpu_thread(4, 32), 14, 0, false).unwrap();
    let plan = FaultPlan::parse("drop@500:w0;drop@1500:w1;drop@2500:w2;drop@3500:w3").unwrap();
    let e = Exec::gpu_thread(4, 32).faults(plan);
    let out = runners::run_fib(&e, 14, 0, false).unwrap();
    if out.stats.faults_injected > 0 {
        assert!(out.stats.watchdog_trips >= 1, "{:?}", out.stats);
        assert!(
            out.stats.tasks_reexecuted >= out.stats.faults_injected,
            "{:?}",
            out.stats
        );
    }
    assert_eq!(out.stats.tasks_finished, base.stats.tasks_finished);
    assert_eq!(out.stats.root_result, base.stats.root_result);
}

#[test]
fn kill_with_sm_tier_reclaims_pooled_work() {
    // Share-mode SM pools hold sibling tasks; killing workers must not
    // strand them (the pool drain counts as hits, so the spills == hits
    // quiescence invariant survives chaos too).
    let base = runners::run_fib(&Exec::gpu_thread(4, 32).sm_tier(SmTier::Share), 13, 0, false)
        .unwrap();
    let e = Exec::gpu_thread(4, 32)
        .sm_tier(SmTier::Share)
        .faults(FaultPlan::parse("kill@1000:w2;kill@4000:w0").unwrap());
    let out = runners::run_fib(&e, 13, 0, false).unwrap();
    assert_eq!(out.stats.tasks_finished, base.stats.tasks_finished);
    assert_eq!(out.stats.root_result, base.stats.root_result);
    assert_eq!(out.stats.sm_spills, out.stats.sm_pool_hits, "{:?}", out.stats);
}

#[test]
fn deadline_overrun_drains_the_run() {
    // deadline@0 fires before any work happens: the run must terminate
    // immediately through Scheduler::drain with no result and no leaked
    // records, not error out.
    let cfg = GtapConfig {
        grid_size: 2,
        block_size: 64,
        faults: FaultPlan::parse("deadline@0").unwrap(),
        ..Default::default()
    };
    let mut s = Session::compile(&fib::source(0, false), cfg, DeviceSpec::h100()).unwrap();
    let stats = s.run("fib", &[Value::from_i64(20)]).unwrap();
    assert!(stats.drained);
    assert!(stats.root_result.is_none());
    assert_eq!(stats.tasks_finished, 0);
}

#[test]
fn seeded_chaos_schedules_terminate_with_exact_results() {
    // The differential sweep: seeded random fault schedules × workloads ×
    // scheduler organizations/policies. Each runner validates its result
    // against the native reference, and task counts are pinned against
    // the fault-free baseline of the same configuration — bit-for-bit
    // result equality under chaos.
    let execs: Vec<(&str, Exec)> = vec![
        ("default", Exec::gpu_thread(4, 32)),
        (
            "recommended+share",
            Exec::gpu_thread(4, 32)
                .policy(gtap::coordinator::PolicyConfig::recommended())
                .sm_tier(SmTier::Share),
        ),
        ("chaselev", Exec::gpu_thread(4, 32).scheduler(SchedulerKind::SequentialChaseLev)),
        ("global", Exec::gpu_thread(4, 32).scheduler(SchedulerKind::GlobalQueue)),
    ];
    for (label, e) in &execs {
        type Work = (&'static str, Box<dyn Fn(&Exec) -> gtap::Result<runners::Outcome>>);
        let workloads: Vec<Work> = vec![
            ("fib", Box::new(|e: &Exec| runners::run_fib(e, 12, 0, false))),
            ("tree", Box::new(|e: &Exec| runners::run_full_tree(e, 5, 4, 4, None))),
            ("msort", Box::new(|e: &Exec| runners::run_mergesort(e, 64, 8, 1))),
            (
                "nqueens",
                Box::new(|e: &Exec| runners::run_nqueens(&e.clone().no_taskwait(), 6, 2, false)),
            ),
        ];
        for (wname, work) in &workloads {
            let base = work(e).unwrap_or_else(|err| panic!("{label}/{wname} baseline: {err}"));
            for seed in [1u64, 3, 5, 7] {
                let chaotic = e.clone().faults(FaultPlan::seeded(seed, 6));
                let out = work(&chaotic).unwrap_or_else(|err| {
                    panic!("{label}/{wname} seed {seed} failed: {err}")
                });
                assert_eq!(
                    out.stats.tasks_finished, base.stats.tasks_finished,
                    "{label}/{wname} seed {seed}: every task finishes exactly once"
                );
                assert_eq!(
                    out.stats.root_result, base.stats.root_result,
                    "{label}/{wname} seed {seed}"
                );
            }
        }
    }
}

#[test]
fn seeded_bfs_block_level_survives_chaos() {
    // Block-level granularity takes the superblock-fused dispatch path
    // with block-wide workers; recovery must hold there too.
    let e = Exec::gpu_block(4, 32).no_taskwait();
    let base = runners::run_bfs(&e, 64, 3, 2).unwrap();
    for seed in [2u64, 9] {
        let out = runners::run_bfs(&e.clone().faults(FaultPlan::seeded(seed, 6)), 64, 3, 2)
            .unwrap_or_else(|err| panic!("bfs seed {seed}: {err}"));
        assert_eq!(out.stats.tasks_finished, base.stats.tasks_finished, "seed {seed}");
    }
}

#[test]
fn multi_tenant_chaos_preserves_each_tenants_results() {
    // Seeded fault plans (kills, stalls, steal failures, drops) against a
    // co-scheduled multi-tenant round: recovery must keep *every*
    // tenant's slice exact — per-tenant result and task count pinned to
    // fault-free solo baselines.
    let cfg = GtapConfig {
        grid_size: 4,
        block_size: 32,
        ..Default::default()
    };
    let src = fib::source(0, false);
    let solo = |n: i64| {
        let mut s = Session::compile(&src, cfg.clone(), DeviceSpec::h100()).unwrap();
        s.run("fib", &[Value::from_i64(n)]).unwrap()
    };
    let (base_a, base_b) = (solo(12), solo(10));
    for seed in [1u64, 5, 9] {
        let mut chaotic = cfg.clone();
        chaotic.faults = FaultPlan::seeded(seed, 6);
        let mut eng =
            ServiceEngine::new(chaotic, DeviceSpec::h100(), AdmissionPolicy::FairShare)
                .unwrap();
        let a = eng.open_session("a", &src).unwrap();
        let b = eng.open_session("b", &src).unwrap();
        eng.submit(a, "fib", &[Value::from_i64(12)], SubmitOpts::default())
            .unwrap();
        eng.submit(b, "fib", &[Value::from_i64(10)], SubmitOpts::default())
            .unwrap();
        eng.run_to_idle().unwrap();
        assert_eq!(eng.rounds(), 1, "seed {seed}: one co-scheduled round");
        let outs = eng.take_outcomes();
        for (tenant, base) in [(a, &base_a), (b, &base_b)] {
            let o = outs.iter().find(|o| o.tenant == tenant).unwrap();
            assert_eq!(o.status, JobStatus::Completed, "seed {seed}");
            assert_eq!(o.result, base.root_result, "seed {seed}");
            assert_eq!(
                o.stats.tasks_finished, base.tasks_finished,
                "seed {seed}: every task of tenant {tenant} finishes exactly once"
            );
        }
    }
}

#[test]
fn deadline_eviction_under_chaos_spares_co_tenants() {
    // A worker kill lands mid-round while one tenant overruns its
    // deadline: only the deadlined tenant is evicted, and the survivor's
    // slice stays pinned to its fault-free solo baseline.
    let cfg = GtapConfig {
        grid_size: 4,
        block_size: 32,
        ..Default::default()
    };
    let src = fib::source(0, false);
    let solo = {
        let mut s = Session::compile(&src, cfg.clone(), DeviceSpec::h100()).unwrap();
        s.run("fib", &[Value::from_i64(12)]).unwrap()
    };
    let mut chaotic = cfg;
    chaotic.faults = FaultPlan::parse("kill@2000:w1").unwrap();
    let mut eng =
        ServiceEngine::new(chaotic, DeviceSpec::h100(), AdmissionPolicy::FairShare).unwrap();
    let keep = eng.open_session("keep", &src).unwrap();
    let evict = eng.open_session("evict", &src).unwrap();
    eng.submit(keep, "fib", &[Value::from_i64(12)], SubmitOpts::default())
        .unwrap();
    eng.submit(
        evict,
        "fib",
        &[Value::from_i64(20)],
        SubmitOpts {
            deadline: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    let k = outs.iter().find(|o| o.tenant == keep).unwrap();
    let e = outs.iter().find(|o| o.tenant == evict).unwrap();
    assert_eq!(e.status, JobStatus::Evicted);
    assert_eq!(e.stats.tasks_finished, 0, "evicted before any task ran");
    assert_eq!(k.status, JobStatus::Completed);
    assert_eq!(k.result, solo.root_result);
    assert_eq!(k.stats.tasks_finished, solo.tasks_finished);
    assert!(!k.fleet.drained, "scoped eviction is not a whole-run drain");
}

#[test]
fn seeded_plans_reproduce_exactly() {
    // Same seed → same plan → same run, counter for counter.
    let plan = FaultPlan::seeded(11, 8);
    assert!(plan.events.iter().any(|e| e.kind != FaultKind::Kill));
    let run = || {
        runners::run_fib(&Exec::gpu_thread(4, 32).faults(plan.clone()), 12, 0, false)
            .unwrap()
            .stats
    };
    assert_eq!(run(), run());
}
