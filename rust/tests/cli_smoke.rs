//! CLI smoke tests: the `gtap` binary as a subprocess.
//!
//! Pins the panic-free config/CLI surface: bad flags and bad fault specs
//! exit nonzero with a diagnostic on stderr (never a panic backtrace),
//! usage errors exit 2, and the documented good paths exit 0 with their
//! expected report lines — including the `--faults` / `GTAP_FAULTS`
//! surface.

use std::process::{Command, Output};

fn gtap(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_gtap"))
        .args(args)
        .env_remove("GTAP_FAULTS")
        .output()
        .expect("spawn gtap")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn no_args_prints_usage_and_exits_2() {
    let out = gtap(&[]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("usage: gtap"), "{}", stderr(&out));
    assert!(stderr(&out).contains("--faults"), "usage documents the fault surface");
}

#[test]
fn unknown_subcommand_exits_2() {
    let out = gtap(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn bad_numeric_flag_is_a_diagnostic_not_a_panic() {
    let out = gtap(&["run", "fib", "--n", "abc"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("invalid value for --n"), "{err}");
    assert!(!err.contains("panicked"), "must fail via Result, not panic: {err}");
}

#[test]
fn unknown_benchmark_is_a_diagnostic() {
    let out = gtap(&["run", "nosuchbench", "--n", "5"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown benchmark"), "{}", stderr(&out));
}

#[test]
fn bad_fault_spec_is_a_diagnostic() {
    let out = gtap(&["run", "fib", "--n", "10", "--faults", "explode@10:w0"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown fault kind"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn bad_fault_env_is_a_diagnostic() {
    let out = Command::new(env!("CARGO_BIN_EXE_gtap"))
        .args(["run", "fib", "--n", "10"])
        .env("GTAP_FAULTS", "stall@oops:w0:5")
        .output()
        .expect("spawn gtap");
    assert!(!out.status.success());
    assert!(stderr(&out).contains("invalid time"), "{}", stderr(&out));
}

#[test]
fn fault_run_reports_and_validates() {
    // a CLI chaos run: the binary validates the result internally, prints
    // the fault report line, and exits 0
    let out = gtap(&[
        "run", "fib", "--n", "12", "--grid", "4", "--block", "32", "--faults", "kill@0:w1",
    ]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let txt = stdout(&out);
    assert!(txt.contains("faults:"), "{txt}");
    assert!(txt.contains("1 workers lost"), "{txt}");
    assert!(txt.contains("result: 144"), "{txt}");
}

#[test]
fn fault_env_feeds_the_run() {
    let out = Command::new(env!("CARGO_BIN_EXE_gtap"))
        .args(["run", "fib", "--n", "12", "--grid", "4", "--block", "32"])
        .env("GTAP_FAULTS", "stall@0:w0:4000")
        .output()
        .expect("spawn gtap");
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("faults: 1 injected"), "{}", stdout(&out));
}

#[test]
fn cli_flag_overrides_fault_env() {
    let out = Command::new(env!("CARGO_BIN_EXE_gtap"))
        .args(["run", "fib", "--n", "12", "--faults", "off"])
        .env("GTAP_FAULTS", "kill@0:w1")
        .output()
        .expect("spawn gtap");
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    assert!(!stdout(&out).contains("faults:"), "{}", stdout(&out));
}

#[test]
fn config_prints_the_fault_default() {
    let out = gtap(&["config"]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("GTAP_FAULTS               = off"), "{}", stdout(&out));
}
