//! The policy conformance harness: one table-driven sweep over
//! `PolicyConfig::conformance_matrix()` (every scheduling-policy
//! combination across all six axes, ~90 combos) asserting, for each:
//!
//! (a) **correctness** — the validated workload runners (fib against its
//!     closed form; nqueens and the synthetic tree for the new-axis
//!     combos) accept every run;
//! (b) **determinism** — two runs with the same seed produce identical
//!     `RunStats`, and a different seed still validates;
//! (c) **thread-count stability** — sweeping the whole matrix through the
//!     parallel bench harness under `GTAP_BENCH_THREADS=1` vs `4` yields
//!     byte-identical `RunStats` per combo.
//!
//! This file replaces the ad-hoc loops of the former
//! `tests/policy_matrix.rs`; the organization-specific zero-steal
//! regressions moved to `tests/edge_cases.rs`.

use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::parallel_map;
use gtap::coordinator::{
    Placement, PolicyConfig, QueueSelect, RunStats, SmTier, StealAmount,
};
use std::sync::Mutex;

/// EPAQ (3 queues) so queue selection and placement bands have real
/// choices to make; 2 blocks × 4 warps = 8 workers across two SMs, so
/// steals happen, locality-first has genuine same-SM peers, and the
/// Share tier actually pools tasks (every worker on its own SM would make
/// the 24 SM-tier combos vacuous).
fn run_fib_with(p: PolicyConfig, seed: u64) -> RunStats {
    let e = Exec::gpu_thread(2, 128).queues(3).seed(seed).policy(p);
    runners::run_fib(&e, 13, 2, true).unwrap().stats
}

/// Whether a combo exercises any of the PR-3 policy axes (priority
/// acquisition/placement, adaptive steal sizing, the per-SM tier).
fn uses_new_axis(p: &PolicyConfig) -> bool {
    p.queue_select == QueueSelect::Priority
        || matches!(p.placement, Placement::PriorityDepth | Placement::PriorityUser)
        || p.steal_amount == StealAmount::Adaptive
        || p.sm_tier != SmTier::Off
}

#[test]
fn every_combo_is_correct_and_deterministic() {
    for p in PolicyConfig::conformance_matrix() {
        let a = run_fib_with(p, 1);
        let b = run_fib_with(p, 1);
        assert_eq!(a, b, "non-deterministic under {}", p.label());
        // run_fib validated the result; sanity-check the flow stats too
        assert_eq!(a.tasks_finished, a.spawns + 1, "{}", p.label());
        assert!(a.steals_ok <= a.steal_attempts, "{}", p.label());
        // quiescence drains the SM pools completely
        assert_eq!(a.sm_pool_hits, a.sm_spills, "{}", p.label());
        if p.sm_tier == SmTier::Off {
            assert_eq!(a.sm_spills, 0, "{}", p.label());
        }
        // a different seed still computes the same (validated) result
        run_fib_with(p, 2);
    }
}

#[test]
fn new_axis_combos_validate_on_every_workload_family() {
    // fib is covered for the full matrix above; the combos that exercise
    // the new axes also run the spawn-only (nqueens) and payload-tree
    // families end to end, each validated against its native reference.
    for p in PolicyConfig::conformance_matrix() {
        if !uses_new_axis(&p) {
            continue;
        }
        // 1 block × 4 warps: all four workers are same-SM peers, so the
        // SM-tier combos route real traffic through the pool here too
        let e = Exec::gpu_thread(1, 128).queues(2).no_taskwait().policy(p);
        runners::run_nqueens(&e, 6, 3, true).unwrap();
        let e = Exec::gpu_thread(1, 128).queues(3).policy(p);
        runners::run_full_tree(&e, 5, 2, 4, None).unwrap();
    }
}

#[test]
fn distinct_policies_actually_schedule_differently() {
    // the axes must be observable, not cosmetic: steal-one claims less per
    // steal than batched, so it needs at least as many successful steals,
    // and strictly more pops+steals overall on a steal-heavy run
    let batched = run_fib_with(PolicyConfig::default(), 5);
    let one = run_fib_with(
        PolicyConfig {
            steal_amount: StealAmount::Fixed { max: Some(1) },
            ..Default::default()
        },
        5,
    );
    assert_eq!(batched.tasks_finished, one.tasks_finished);
    assert_ne!(
        (batched.cycles, batched.steals_ok, batched.pops),
        (one.cycles, one.steals_ok, one.pops),
        "steal-one must be observably different from batched stealing"
    );
}

#[test]
fn share_tier_actually_pools_tasks() {
    // SmTier::Share must generate pool traffic on a multi-worker-per-SM
    // run (8 blocks on an H100 land on 8 distinct SMs, so use 2 blocks ×
    // 4 warps: 4 same-SM peers per block)
    let p = PolicyConfig {
        sm_tier: SmTier::Share,
        ..Default::default()
    };
    let e = Exec::gpu_thread(2, 128).queues(3).policy(p);
    let s = runners::run_fib(&e, 13, 2, true).unwrap().stats;
    assert!(s.sm_spills > 0, "share tier never pooled a task: {s:?}");
    assert_eq!(s.sm_pool_hits, s.sm_spills);
}

#[test]
fn rr_spill_survives_tight_queue_capacity() {
    // rr-spill's contract: tight per-class budgets must not abort the run;
    // overflowing batches split across the classes by free space. The run
    // is validated (run_fib checks the closed form), so any misrouted or
    // dropped child shows up as a wrong result.
    let mut e = Exec::gpu_thread(2, 32).queues(3).queue_capacity(64);
    e.cfg.policy.placement = Placement::RoundRobinSpill;
    runners::run_fib(&e, 14, 2, true).unwrap();
}

#[test]
fn sm_tier_spill_absorbs_overflow_before_the_cross_class_split() {
    // under the same tight budget as the rr-spill test, an enabled Spill
    // tier must be the first overflow resort: the pool sees traffic, the
    // run still validates (rr-spill stays on as the backstop so the test
    // can't abort on a deeper burst than the pool holds)
    let mut e = Exec::gpu_thread(2, 32).queues(3).queue_capacity(64);
    e.cfg.policy.placement = Placement::RoundRobinSpill;
    e.cfg.policy.sm_tier = SmTier::Spill;
    let s = runners::run_fib(&e, 14, 2, true).unwrap().stats;
    assert!(s.sm_spills > 0, "tight capacity must overflow into the pool");
    assert_eq!(s.sm_pool_hits, s.sm_spills);
}

/// Serializes access to the GTAP_BENCH_* environment within this binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn with_env<R>(pairs: &[(&str, &str)], f: impl FnOnce() -> R) -> R {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (k, v) in pairs {
        std::env::set_var(k, v);
    }
    let r = f();
    for (k, _) in pairs {
        std::env::remove_var(k);
    }
    r
}

#[test]
fn run_stats_identical_across_bench_thread_counts() {
    // the full conformance matrix as one sweep: serial vs 4 harness
    // threads must produce byte-identical RunStats per combo (the
    // bench-layer determinism contract extends to every policy axis)
    let combos = PolicyConfig::conformance_matrix();
    let sweep = || parallel_map(PolicyConfig::conformance_matrix(), |p| run_fib_with(p, 7));
    let serial = with_env(&[("GTAP_BENCH_THREADS", "1")], sweep);
    let parallel = with_env(&[("GTAP_BENCH_THREADS", "4")], sweep);
    assert_eq!(serial.len(), combos.len());
    assert_eq!(parallel.len(), combos.len());
    for ((a, b), p) in serial.iter().zip(parallel.iter()).zip(combos.iter()) {
        assert_eq!(a, b, "thread count changed RunStats under {}", p.label());
    }
}
