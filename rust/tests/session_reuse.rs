//! Session-reuse semantics, pinned across every scheduler organization
//! and both memory-system models.
//!
//! The contract (`coordinator::Session` docs): simulated global memory
//! persists across runs — the host sets up arrays, runs, reads results
//! back, runs again — while task-management state (records, queues,
//! stats) is rebuilt per run like a fresh kernel launch. With the
//! lower-once fix the session also reuses its cached lowering, so these
//! tests double as drift detection: a warm session's Nth run must stay
//! byte-identical to a cold session's first.

use gtap::coordinator::{GtapConfig, RunStats, SchedulerKind, Session};
use gtap::ir::types::Value;
use gtap::sim::{DeviceSpec, MemSysMode};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

const ACCUM: &str = r#"
    global int g_sum;
    #pragma gtap function
    void acc(ptr p, int n) {
        int i = 0;
        int s = 0;
        while (i < n) { s = s + p[i]; i = i + 1; }
        g_sum = g_sum + s;
    }
"#;

const KINDS: [SchedulerKind; 3] = [
    SchedulerKind::WorkStealing,
    SchedulerKind::GlobalQueue,
    SchedulerKind::SequentialChaseLev,
];

const MEMSYS: [MemSysMode; 2] = [MemSysMode::Flat, MemSysMode::Modeled];

fn cfg(kind: SchedulerKind, memsys: MemSysMode) -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 32,
        scheduler: kind,
        memsys,
        ..Default::default()
    }
}

fn no_carryover(a: &RunStats, b: &RunStats) {
    // task state resets per run: counters restart from zero instead of
    // accumulating, and the run is bit-reproducible
    assert_eq!(a, b);
    assert_eq!(a.tasks_finished, a.spawns + 1);
}

#[test]
fn repeated_runs_are_byte_identical_for_every_kind_and_memsys() {
    for kind in KINDS {
        for memsys in MEMSYS {
            let label = format!("{kind:?}/{memsys:?}");
            let mut s =
                Session::compile(FIB, cfg(kind, memsys), DeviceSpec::h100()).unwrap();
            let r1 = s.run("fib", &[Value::from_i64(11)]).unwrap();
            let r2 = s.run("fib", &[Value::from_i64(11)]).unwrap();
            let r3 = s.run("fib", &[Value::from_i64(11)]).unwrap();
            assert_eq!(r1.root_result.unwrap().as_i64(), 89, "{label}");
            no_carryover(&r1, &r2);
            no_carryover(&r2, &r3);
            // warm runs also match a cold session exactly
            let mut fresh =
                Session::compile(FIB, cfg(kind, memsys), DeviceSpec::h100()).unwrap();
            let f1 = fresh.run("fib", &[Value::from_i64(11)]).unwrap();
            assert_eq!(r3, f1, "{label}: warm run 3 == cold run 1");
        }
    }
}

#[test]
fn globals_and_arrays_persist_while_task_state_resets() {
    for kind in KINDS {
        for memsys in MEMSYS {
            let label = format!("{kind:?}/{memsys:?}");
            let mut s =
                Session::compile(ACCUM, cfg(kind, memsys), DeviceSpec::h100()).unwrap();
            let p = s.alloc(4);
            s.memory.write_i64s(p, &[1, 2, 3, 4]);
            let args = [Value(p), Value::from_i64(4)];
            let r1 = s.run("acc", &args).unwrap();
            // the global accumulates across runs (memory persists) ...
            assert_eq!(s.get_global("g_sum").unwrap().as_i64(), 10, "{label}");
            let r2 = s.run("acc", &args).unwrap();
            assert_eq!(s.get_global("g_sum").unwrap().as_i64(), 20, "{label}");
            // ... while per-run task accounting does not
            assert_eq!(r1.tasks_finished, r2.tasks_finished, "{label}");
            assert_eq!(r1.cycles, r2.cycles, "{label}");
            // the host array is still intact and re-writable
            assert_eq!(s.memory.read_i64s(p, 4), vec![1, 2, 3, 4], "{label}");
            s.memory.write_i64s(p, &[10, 0, 0, 0]);
            s.run("acc", &args).unwrap();
            assert_eq!(s.get_global("g_sum").unwrap().as_i64(), 30, "{label}");
        }
    }
}

#[test]
fn modeled_memsys_differs_only_in_memsys_counters_across_reuse() {
    // Sanity for the matrix itself: flat vs modeled agree on results and
    // task counts on a reused session (cycles legitimately differ).
    for kind in KINDS {
        let mut flat =
            Session::compile(FIB, cfg(kind, MemSysMode::Flat), DeviceSpec::h100()).unwrap();
        let mut modeled =
            Session::compile(FIB, cfg(kind, MemSysMode::Modeled), DeviceSpec::h100())
                .unwrap();
        for _ in 0..2 {
            let f = flat.run("fib", &[Value::from_i64(10)]).unwrap();
            let m = modeled.run("fib", &[Value::from_i64(10)]).unwrap();
            assert_eq!(f.root_result, m.root_result, "{kind:?}");
            assert_eq!(f.tasks_finished, m.tasks_finished, "{kind:?}");
            assert_eq!(f.memsys, Default::default(), "{kind:?}: flat records nothing");
        }
    }
}
