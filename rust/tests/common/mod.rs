//! Shared four-tier segment harness for workloads whose segments need
//! prepared global memory (CSR graphs, arrays). One copy, used by both
//! `tests/interp_differential.rs` and `tests/compiler_fuzz.rs`, so the
//! differential and fuzz suites always test identical harness semantics
//! (compile → decode → fuse → trace, record-pool sizing, tier dispatch,
//! the memory checksum fold).
#![allow(dead_code)] // each test binary uses a subset of the surface

use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::ir::traced::TracedModule;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::memsys::MemAccess;
use gtap::sim::{BranchProfile, DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use gtap::workloads::bfs::CsrGraph;

/// The four interpreter tiers under differential test.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Tier {
    Ref,
    Decoded,
    Fused,
    Traced,
}

pub const TIERS: [Tier; 4] = [Tier::Ref, Tier::Decoded, Tier::Fused, Tier::Traced];

/// One tier's observable result on a memory-backed workload segment.
#[derive(Clone, Debug, PartialEq)]
pub struct TierRun {
    pub cycles: u64,
    /// Raw dynamic-path hash — comparable bit-for-bit only between the
    /// decoded and fused tiers (the reference folds function-local pcs).
    pub path: u64,
    pub spawns: usize,
    /// Modeled-memsys access stream (empty under the flat model).
    pub accesses: Vec<MemAccess>,
    /// Multiply-fold checksum over the whole memory image after the
    /// segment, so functional effects are compared too.
    pub mem_checksum: u64,
}

impl TierRun {
    /// Everything except the raw path hash — what all four tiers must
    /// agree on bit for bit.
    pub fn functional(&self) -> (u64, usize, &[MemAccess], u64) {
        (self.cycles, self.spawns, &self.accesses, self.mem_checksum)
    }
}

/// Run one segment of `src`'s function 0 through one tier: `setup`
/// prepares the global memory image and returns the task args; `modeled`
/// selects the recording interpreters (`--memsys modeled` gating).
/// Traced-tier builds use static prediction; see
/// [`run_mem_workload_tier_profiled`] to force a branch profile (e.g. an
/// inverted one, to make every trace side-exit).
pub fn run_mem_workload_tier(
    src: &str,
    state: u16,
    tier: Tier,
    modeled: bool,
    block_width: u32,
    setup: &dyn Fn(&mut Memory) -> Vec<i64>,
) -> TierRun {
    run_mem_workload_tier_profiled(src, state, tier, modeled, block_width, None, setup)
}

/// [`run_mem_workload_tier`] with an explicit branch profile feeding the
/// traced tier's trace formation (ignored by the other tiers).
pub fn run_mem_workload_tier_profiled(
    src: &str,
    state: u16,
    tier: Tier,
    modeled: bool,
    block_width: u32,
    profile: Option<&BranchProfile>,
    setup: &dyn Fn(&mut Memory) -> Vec<i64>,
) -> TierRun {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module
        .funcs
        .iter()
        .map(|f| f.layout.words())
        .max()
        .unwrap()
        .max(1);
    let mut records = RecordPool::new(64, words, 8);
    let mut mem = Memory::new(module.globals_words());
    let args = setup(&mut mem);
    let task = records.alloc(0, NO_TASK).unwrap();
    for (i, &a) in args.iter().enumerate() {
        records.data_mut(task)[i] = a as u64;
    }
    let mut log = Vec::new();
    let (out, spawns, accesses) = match tier {
        Tier::Ref => {
            let interp = RefInterp {
                module: &module,
                dev: &dev,
                block_width,
                xla_payload: false,
                record_accesses: modeled,
            };
            let mut frame = RefLaneFrame::new();
            frame.reset(&module, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().len(), frame.accesses().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
        Tier::Decoded | Tier::Fused | Tier::Traced => {
            let tm;
            let base = match tier {
                Tier::Fused => Interp::fused(&decoded, &fm, &dev, block_width, false),
                Tier::Traced => {
                    tm = TracedModule::build(&decoded, &fm, &dev, profile);
                    Interp::traced(&decoded, &tm, &dev, block_width, false)
                }
                _ => Interp::new(&decoded, &dev, block_width, false),
            };
            let interp = base.recording(modeled);
            let mut frame = LaneFrame::sized(&decoded);
            frame.reset(&decoded, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().len(), frame.accesses().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
    };
    let mem_checksum = (0..mem.size_words())
        .fold(0u64, |s, a| s.wrapping_mul(31).wrapping_add(mem.load(a)));
    TierRun {
        cycles: out.cycles,
        path: out.path,
        spawns,
        accesses,
        mem_checksum,
    }
}

/// Record the decoded tier's branch stream for one segment and return it
/// **inverted**: feeding the result to the traced-tier build makes every
/// biased branch predict against the segment's real hot path, so traces
/// side-exit almost every dispatch — the adversarial case for the traced
/// tier's cost-transparency (spill-at-exit) machinery.
pub fn inverted_profile_for(
    src: &str,
    state: u16,
    block_width: u32,
    setup: &dyn Fn(&mut Memory) -> Vec<i64>,
) -> BranchProfile {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let words = module
        .funcs
        .iter()
        .map(|f| f.layout.words())
        .max()
        .unwrap()
        .max(1);
    let mut records = RecordPool::new(64, words, 8);
    let mut mem = Memory::new(module.globals_words());
    let args = setup(&mut mem);
    let task = records.alloc(0, NO_TASK).unwrap();
    for (i, &a) in args.iter().enumerate() {
        records.data_mut(task)[i] = a as u64;
    }
    let mut log = Vec::new();
    let mut profile = BranchProfile::new(decoded.insns.len());
    let interp = Interp::new(&decoded, &dev, block_width, false);
    let mut frame = LaneFrame::sized(&decoded);
    frame.reset(&decoded, task, 0, state, 0);
    match interp.run_profiled(&mut frame, &mut mem, &mut records, &mut log, &mut profile) {
        StepResult::Done(_) => {}
        other => panic!("unexpected {other:?}"),
    }
    profile.inverted()
}

/// Memory setup for one BFS segment: CSR arrays + the depth vector with
/// the expanded vertex `v` at depth 0 and everything else unreached.
pub fn bfs_setup(graph: &CsrGraph, v: i64) -> impl Fn(&mut Memory) -> Vec<i64> + '_ {
    move |mem: &mut Memory| {
        let ro = mem.alloc(graph.row_offsets.len() as u64);
        let ci = mem.alloc(graph.col_indices.len().max(1) as u64);
        let dp = mem.alloc(graph.n as u64);
        mem.write_i64s(ro, &graph.row_offsets);
        mem.write_i64s(ci, &graph.col_indices);
        mem.write_i64s(dp, &vec![i64::MAX; graph.n]);
        mem.store(dp + v as u64, 0);
        vec![v, ro as i64, ci as i64, dp as i64]
    }
}

/// Memory setup for one mergesort segment over `xs`: data + tmp arrays;
/// a state-1 (post-join) re-entry gets both halves of `[left, right)`
/// pre-sorted, as the children would have left them.
pub fn msort_setup(
    xs: &[i64],
    state: u16,
    left: i64,
    right: i64,
) -> impl Fn(&mut Memory) -> Vec<i64> + '_ {
    move |mem: &mut Memory| {
        let n = xs.len() as u64;
        let data = mem.alloc(n);
        let tmp = mem.alloc(n);
        let mut v = xs.to_vec();
        if state == 1 {
            let mid = ((left + right) / 2) as usize;
            v[left as usize..mid].sort_unstable();
            v[mid..right as usize].sort_unstable();
        }
        mem.write_i64s(data, &v);
        vec![data as i64, left, right, tmp as i64]
    }
}
