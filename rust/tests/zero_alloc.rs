//! Enforces the hot-path contract: **steady-state segment execution
//! performs zero heap allocations**.
//!
//! A counting global allocator wraps the system allocator; after warming
//! the pre-sized [`LaneFrame`] and record pool, the test drives well over
//! 10k segments (recursive, leaf, and post-join continuation shapes)
//! through the decoded, superblock-fused, and trace-fused dispatch loops
//! and asserts the allocation counter never moves. This file holds
//! exactly one test so no sibling test thread can allocate concurrently
//! and pollute the counter.

use gtap::compiler::compile_default;
use gtap::coordinator::config::{GtapConfig, SchedulerKind};
use gtap::coordinator::policy::{adaptive_amount, Placement, QueueSelect, QueueSet, SmPool};
use gtap::coordinator::records::{RecordPool, TaskId, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::ir::traced::TracedModule;
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

#[test]
fn steady_state_segment_execution_is_allocation_free() {
    // ---- setup: allocations are unrestricted here ----------------------
    let module = compile_default(FIB).unwrap();
    let decoded = DecodedModule::decode(&module);
    let words = module.funcs[0].layout.words().max(1);
    let mut records = RecordPool::new(16, words, 4);
    let mut mem = Memory::new(module.globals_words());
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let tm = TracedModule::build(&decoded, &fm, &dev, None);
    let interp = Interp::new(&decoded, &dev, 1, false);
    let interp_fused = Interp::fused(&decoded, &fm, &dev, 1, false);
    let interp_traced = Interp::traced(&decoded, &tm, &dev, 1, false);
    let mut frame = LaneFrame::sized(&decoded);
    let mut log: Vec<String> = Vec::new();

    let task = records.alloc(0, NO_TASK).unwrap();
    // materialize two finished children so state-1 continuations can read
    // their results, as after a real join
    let off = module.funcs[0].layout.result_offset().unwrap() as usize;
    for v in [1u64, 0] {
        let child = records.alloc(0, task).unwrap();
        records.push_child(task, child).unwrap();
        records.data_mut(child)[off] = v;
        records.meta_mut(child).done = true;
    }
    records.meta_mut(task).pending_children = 0;

    // segment mix: recursive first segments, leaves, continuations
    let stream: &[(u16, i64)] = &[(0, 30), (0, 1), (1, 7), (0, 0), (1, 21), (0, 12)];
    let mut run_segment = |frame: &mut LaneFrame,
                           records: &mut RecordPool,
                           mem: &mut Memory,
                           log: &mut Vec<String>,
                           state: u16,
                           n: i64|
     -> u64 {
        records.data_mut(task)[0] = n as u64;
        frame.reset(&decoded, task, 0, state, 0);
        match interp.run(frame, mem, records, log) {
            StepResult::Done(o) => o.cycles,
            other => panic!("unexpected {other:?}"),
        }
    };

    // ---- warm-up: first touches may grow buffers -----------------------
    let mut checksum = 0u64;
    for &(state, n) in stream {
        checksum = checksum.wrapping_add(run_segment(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }

    // ---- measured region: >= 12k segments, zero allocations ------------
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..12_000usize {
        let (state, n) = stream[i % stream.len()];
        checksum = checksum.wrapping_add(run_segment(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert!(checksum > 0, "segments actually executed");
    assert!(log.is_empty(), "fib prints nothing");
    assert_eq!(
        after - before,
        0,
        "the decoded dispatch loop must not allocate in steady state"
    );

    // ---- the superblock-fused engine obeys the same contract ------------
    // (the production path: folded block charges + macro-op streams; the
    // FusedModule itself was built in the setup phase above)
    let mut run_segment_fused = |frame: &mut LaneFrame,
                                 records: &mut RecordPool,
                                 mem: &mut Memory,
                                 log: &mut Vec<String>,
                                 state: u16,
                                 n: i64|
     -> u64 {
        records.data_mut(task)[0] = n as u64;
        frame.reset(&decoded, task, 0, state, 0);
        match interp_fused.run(frame, mem, records, log) {
            StepResult::Done(o) => o.cycles,
            other => panic!("unexpected {other:?}"),
        }
    };
    let mut fused_checksum = 0u64;
    for &(state, n) in stream {
        fused_checksum = fused_checksum.wrapping_add(run_segment_fused(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..12_000usize {
        let (state, n) = stream[i % stream.len()];
        fused_checksum = fused_checksum.wrapping_add(run_segment_fused(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        fused_checksum, checksum,
        "fused dispatch must charge the exact cycles decoded dispatch does"
    );
    assert_eq!(
        after - before,
        0,
        "the fused block dispatch loop must not allocate in steady state"
    );

    // ---- the trace-fused engine obeys the same contract too --------------
    // (the current production path: inline-cached trace lookup, fixed
    // stack scratch array for demoted registers, spill-at-exit; the
    // TracedModule itself was built in the setup phase above)
    let mut run_segment_traced = |frame: &mut LaneFrame,
                                  records: &mut RecordPool,
                                  mem: &mut Memory,
                                  log: &mut Vec<String>,
                                  state: u16,
                                  n: i64|
     -> u64 {
        records.data_mut(task)[0] = n as u64;
        frame.reset(&decoded, task, 0, state, 0);
        match interp_traced.run(frame, mem, records, log) {
            StepResult::Done(o) => o.cycles,
            other => panic!("unexpected {other:?}"),
        }
    };
    let mut traced_checksum = 0u64;
    for &(state, n) in stream {
        traced_checksum = traced_checksum.wrapping_add(run_segment_traced(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..12_000usize {
        let (state, n) = stream[i % stream.len()];
        traced_checksum = traced_checksum.wrapping_add(run_segment_traced(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        traced_checksum, checksum,
        "traced dispatch must charge the exact cycles decoded dispatch does"
    );
    assert_eq!(
        after - before,
        0,
        "the traced dispatch loop must not allocate in steady state"
    );

    // ---- the scheduling-policy hot paths are allocation-free too --------
    // (same single test so no sibling thread pollutes the counter): the
    // priority band scan, priority/continuation placement, the adaptive
    // steal controller, and SM-tier pool traffic on pre-allocated rings.
    let cfg = GtapConfig {
        grid_size: 1,
        block_size: 32,
        num_queues: 4,
        scheduler: SchedulerKind::WorkStealing,
        ..Default::default()
    };
    let mut queues = QueueSet::for_config(&cfg);
    let mut pool = SmPool::new(2, 64);
    let mut out: Vec<TaskId> = Vec::with_capacity(64);
    let ids: [TaskId; 8] = [1, 2, 3, 4, 5, 6, 7, 8];
    let mut cursor = 0usize;
    let mut policy_checksum = 0usize;
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..4_000u64 {
        let pushed = queues.push(0, (i % 4) as usize, i, &ids[..1 + (i % 4) as usize], &dev);
        assert!(pushed.is_some(), "push stays within pre-sized capacity");
        let start = QueueSelect::Priority.start(0, cursor, 4, &queues);
        QueueSelect::Priority.commit(&mut cursor, start);
        out.clear();
        queues.pop(0, start, i, 32, &mut out, &dev);
        policy_checksum += out.len();
        policy_checksum += Placement::PriorityDepth.place(0, cursor, 4, (i % 9) as u16, 0);
        policy_checksum +=
            Placement::PriorityUser.place_continuation(2, 4, 0, (i % 7) as u8);
        policy_checksum += adaptive_amount(i, i / 3, out.len(), 32);
        let pooled = pool.push((i % 2) as usize, i, &ids, &dev);
        assert!(pooled.is_some(), "pool push stays within capacity");
        out.clear();
        pool.pop((i % 2) as usize, i, 32, &mut out, &dev);
        policy_checksum += out.len();
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert!(policy_checksum > 0, "policy paths actually executed");
    assert_eq!(
        after - before,
        0,
        "policy dispatch and SM-tier pool traffic must not allocate"
    );
}
