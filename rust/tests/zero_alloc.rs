//! Enforces the hot-path contract: **steady-state segment execution
//! performs zero heap allocations**.
//!
//! A counting global allocator wraps the system allocator; after warming
//! the pre-sized [`LaneFrame`] and record pool, the test drives well over
//! 10k segments (recursive, leaf, and post-join continuation shapes)
//! through the decoded dispatch loop and asserts the allocation counter
//! never moves. This file holds exactly one test so no sibling test
//! thread can allocate concurrently and pollute the counter.

use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

#[test]
fn steady_state_segment_execution_is_allocation_free() {
    // ---- setup: allocations are unrestricted here ----------------------
    let module = compile_default(FIB).unwrap();
    let decoded = DecodedModule::decode(&module);
    let words = module.funcs[0].layout.words().max(1);
    let mut records = RecordPool::new(16, words, 4);
    let mut mem = Memory::new(module.globals_words());
    let dev = DeviceSpec::h100();
    let interp = Interp::new(&decoded, &dev, 1, false);
    let mut frame = LaneFrame::sized(&decoded);
    let mut log: Vec<String> = Vec::new();

    let task = records.alloc(0, NO_TASK).unwrap();
    // materialize two finished children so state-1 continuations can read
    // their results, as after a real join
    let off = module.funcs[0].layout.result_offset().unwrap() as usize;
    for v in [1u64, 0] {
        let child = records.alloc(0, task).unwrap();
        records.push_child(task, child).unwrap();
        records.data_mut(child)[off] = v;
        records.meta_mut(child).done = true;
    }
    records.meta_mut(task).pending_children = 0;

    // segment mix: recursive first segments, leaves, continuations
    let stream: &[(u16, i64)] = &[(0, 30), (0, 1), (1, 7), (0, 0), (1, 21), (0, 12)];
    let mut run_segment = |frame: &mut LaneFrame,
                           records: &mut RecordPool,
                           mem: &mut Memory,
                           log: &mut Vec<String>,
                           state: u16,
                           n: i64|
     -> u64 {
        records.data_mut(task)[0] = n as u64;
        frame.reset(&decoded, task, 0, state, 0);
        match interp.run(frame, mem, records, log) {
            StepResult::Done(o) => o.cycles,
            other => panic!("unexpected {other:?}"),
        }
    };

    // ---- warm-up: first touches may grow buffers -----------------------
    let mut checksum = 0u64;
    for &(state, n) in stream {
        checksum = checksum.wrapping_add(run_segment(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }

    // ---- measured region: >= 12k segments, zero allocations ------------
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..12_000usize {
        let (state, n) = stream[i % stream.len()];
        checksum = checksum.wrapping_add(run_segment(
            &mut frame,
            &mut records,
            &mut mem,
            &mut log,
            state,
            n,
        ));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert!(checksum > 0, "segments actually executed");
    assert!(log.is_empty(), "fib prints nothing");
    assert_eq!(
        after - before,
        0,
        "the decoded dispatch loop must not allocate in steady state"
    );
}
