//! End-to-end and property pins for the modeled memory system
//! (`sim::memsys`, `--memsys flat|modeled`):
//!
//! * **flat is the golden default** — a default run IS a flat run, its
//!   memsys counters are all zero, and `RunStats` match the explicit
//!   `--memsys flat` spelling byte for byte;
//! * **modeled stays correct and deterministic** — every workload family
//!   still validates against its native reference, two same-seed runs are
//!   bit-identical, and a whole sweep is byte-identical across
//!   `GTAP_BENCH_THREADS=1` vs `4`;
//! * **coalescing is the lever** — a scattered synthetic stream costs
//!   strictly more modeled cycles than the same stream coalesced
//!   (property-tested over random bases/widths, `queue_model.rs` style);
//! * **the SM-tier pools are re-costed** — modeled runs price pool
//!   traffic by shared-memory banks instead of the 60% discount.

use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::parallel_map;
use gtap::coordinator::{RunStats, SmTier};
use gtap::sim::divergence::LanePath;
use gtap::sim::memsys::{coalesce, AccessKind, MemAccess, MemSys, MemSysMode, MemSysStats};
use gtap::sim::DeviceSpec;
use gtap::util::prop::Runner;
use std::sync::Mutex;

fn fib_stats(e: &Exec) -> RunStats {
    runners::run_fib(e, 13, 0, false).unwrap().stats
}

#[test]
fn flat_default_is_byte_identical_with_zero_counters() {
    let default = fib_stats(&Exec::gpu_thread(4, 32));
    let explicit = fib_stats(&Exec::gpu_thread(4, 32).memsys(MemSysMode::Flat));
    assert_eq!(default, explicit, "flat must be the default spelling");
    assert_eq!(default.memsys, MemSysStats::default(), "flat counts nothing");
}

#[test]
fn modeled_runs_validate_and_count_traffic() {
    // thread-level fib + mergesort, block-level tree + bfs: every family
    // validates against its native reference under the modeled memsys
    let e = Exec::gpu_thread(4, 32).memsys(MemSysMode::Modeled);
    let s = fib_stats(&e);
    assert!(s.memsys.transactions > 0, "fib touches task records: {s:?}");
    assert!(
        s.memsys.l2_hits + s.memsys.l2_misses > 0,
        "transactions must probe the hierarchy"
    );
    runners::run_mergesort(&e, 600, 32, 1).unwrap();
    runners::run_full_tree(&Exec::gpu_block(4, 64).memsys(MemSysMode::Modeled), 5, 8, 8, None)
        .unwrap();
    let bfs = runners::run_bfs(
        &Exec::gpu_block(4, 64).no_taskwait().memsys(MemSysMode::Modeled),
        120,
        3,
        5,
    )
    .unwrap()
    .stats;
    assert!(
        bfs.memsys.transactions > 0,
        "bfs walks CSR arrays: {:?}",
        bfs.memsys
    );
    assert!(
        bfs.memsys.sectors >= bfs.memsys.transactions,
        "every 128B transaction touches at least one 32B sector"
    );
}

#[test]
fn modeled_is_deterministic_and_observably_different_from_flat() {
    let modeled = || fib_stats(&Exec::gpu_thread(4, 32).memsys(MemSysMode::Modeled));
    let a = modeled();
    let b = modeled();
    assert_eq!(a, b, "modeled runs must be deterministic");
    let flat = fib_stats(&Exec::gpu_thread(4, 32));
    assert_eq!(a.root_result, flat.root_result, "semantics are mode-independent");
    assert_eq!(a.tasks_finished, flat.tasks_finished);
    assert_ne!(a.cycles, flat.cycles, "the model must actually change costs");
}

#[test]
fn prop_scattered_streams_cost_strictly_more_than_coalesced() {
    // The defining property of the coalescer: for any base address and
    // warp width, spreading the same per-lane access count across
    // distinct 128B lines costs strictly more than packing it into
    // consecutive words — cold caches, same kind, same path group.
    Runner::new().cases(200).run("memsys-coalescing", |g| {
        let dev = DeviceSpec::h100();
        let lanes_n = g.usize(2, 32);
        // line-aligned base so "coalesced" means exactly one line/position
        let base = g.int(0, 1 << 20) as u64 * coalesce::LINE_WORDS;
        let positions = g.usize(1, 4);
        let lanes: Vec<LanePath> =
            (0..lanes_n).map(|_| LanePath { hash: 7, cycles: 0 }).collect();
        let stream = |lane: u64, scattered: bool| -> Vec<MemAccess> {
            (0..positions as u64)
                .map(|p| {
                    let addr = if scattered {
                        // one line per lane per position
                        base + (p * 33 + lane) * coalesce::LINE_WORDS
                    } else {
                        // all lanes inside one line per position
                        base + p * coalesce::LINE_WORDS + lane % coalesce::LINE_WORDS
                    };
                    MemAccess {
                        addr,
                        kind: AccessKind::GlobalLoad,
                    }
                })
                .collect()
        };
        let cost = |scattered: bool| {
            let streams: Vec<Vec<MemAccess>> =
                (0..lanes_n as u64).map(|l| stream(l, scattered)).collect();
            let mut m = MemSys::modeled(&dev);
            let mut stats = MemSysStats::default();
            let c = m.charge_warp(0, &lanes, |i| &streams[i][..], &dev, &mut stats);
            (c, stats.transactions)
        };
        let (scattered, scattered_tx) = cost(true);
        let (coalesced, coalesced_tx) = cost(false);
        assert!(
            scattered > coalesced,
            "scattered {scattered} must exceed coalesced {coalesced} \
             (lanes {lanes_n}, positions {positions}, base {base})"
        );
        assert!(scattered_tx > coalesced_tx);
        assert_eq!(coalesced_tx, positions as u64, "one line per position");
    });
}

#[test]
fn modeled_sm_tier_prices_pools_by_banks_not_discount() {
    // same seed, same share-tier policy: flat vs modeled runs must differ
    // in cost (the pool pricing changed) while both validate and both
    // drain their pools completely
    let exec = |m: MemSysMode| {
        let mut e = Exec::gpu_thread(2, 128).queues(3).memsys(m);
        e.cfg.policy.sm_tier = SmTier::Share;
        e
    };
    let flat = runners::run_fib(&exec(MemSysMode::Flat), 13, 2, true).unwrap().stats;
    let modeled = runners::run_fib(&exec(MemSysMode::Modeled), 13, 2, true).unwrap().stats;
    assert!(flat.sm_spills > 0, "share tier must pool tasks: {flat:?}");
    assert!(modeled.sm_spills > 0);
    assert_eq!(flat.sm_pool_hits, flat.sm_spills);
    assert_eq!(modeled.sm_pool_hits, modeled.sm_spills);
    assert_ne!(flat.cycles, modeled.cycles, "pool pricing must differ");
    assert_eq!(flat.memsys.smem_bank_conflicts, 0, "flat never counts banks");
}

/// Serializes access to the GTAP_BENCH_* environment within this binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn with_env<R>(pairs: &[(&str, &str)], f: impl FnOnce() -> R) -> R {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (k, v) in pairs {
        std::env::set_var(k, v);
    }
    let r = f();
    for (k, _) in pairs {
        std::env::remove_var(k);
    }
    r
}

#[test]
fn modeled_run_stats_identical_across_bench_thread_counts() {
    // the acceptance pin: a modeled sweep through the parallel bench
    // harness yields byte-identical RunStats under 1 vs 4 threads
    let grids: Vec<usize> = vec![1, 2, 4, 8];
    let sweep = || {
        parallel_map(grids.clone(), |g| {
            fib_stats(&Exec::gpu_thread(g, 32).memsys(MemSysMode::Modeled))
        })
    };
    let serial = with_env(&[("GTAP_BENCH_THREADS", "1")], sweep);
    let parallel = with_env(&[("GTAP_BENCH_THREADS", "4")], sweep);
    assert_eq!(serial.len(), parallel.len());
    for ((a, b), g) in serial.iter().zip(parallel.iter()).zip(grids.iter()) {
        assert_eq!(a, b, "thread count changed modeled RunStats at grid {g}");
    }
}

#[test]
fn modeled_mode_holds_across_queue_organizations() {
    use gtap::coordinator::SchedulerKind;
    for kind in [
        SchedulerKind::WorkStealing,
        SchedulerKind::GlobalQueue,
        SchedulerKind::SequentialChaseLev,
    ] {
        let e = Exec::gpu_thread(4, 32).scheduler(kind).memsys(MemSysMode::Modeled);
        let s = fib_stats(&e);
        assert!(s.memsys.transactions > 0, "{kind:?}");
    }
}
