//! Differential testing across the three interpreter tiers on identical
//! segment streams:
//!
//! * `sim::interp_ref` — the module-walking **reference**;
//! * `sim::interp` over `ir::decoded` — flattened per-instruction
//!   **decoded** dispatch;
//! * `Interp::fused` over `ir::superblock` — block-at-a-time **fused**
//!   dispatch with folded costs and macro-ops (the production engine).
//!
//! For every program/input/state: same segment end, same simulated cycle
//! charge, same spawn list across all three. Path hashes are
//! **bit-identical between decoded and fused** (both fold global pcs; the
//! superblock invariant). The reference folds *function-local* pcs, so its
//! raw hash values legitimately differ; against it only the
//! *path-equality structure* — the sole thing the divergence model
//! consumes — must coincide.

use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, SegmentOutput, SpawnReq, StepResult};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task queue(1)
        a = fib(n - 1);
        #pragma gtap task queue(1)
        b = fib(n - 2);
        #pragma gtap taskwait queue(2)
        return a + b;
    }
"#;

const LOOPY: &str = "#pragma gtap function\nint sum(int n) {\n\
                     int s = 0;\nfor (int i = 1; i <= n; i += 1) { s = s + i * i; }\n\
                     return s; }";

const INTRINSIC: &str = "#pragma gtap function\nint f(int n) { return fib_serial(n); }";

const PAYLOAD: &str = "#pragma gtap function\nfloat f(int s) { return payload(s, 8, 16); }";

#[derive(Clone, Copy, Debug, PartialEq)]
enum Tier {
    Ref,
    Decoded,
    Fused,
}

const TIERS: [Tier; 3] = [Tier::Ref, Tier::Decoded, Tier::Fused];

/// Run one segment through one tier on identical fresh state.
fn run_tier(src: &str, args: &[i64], state: u16, tier: Tier) -> (SegmentOutput, Vec<SpawnReq>) {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module
        .funcs
        .iter()
        .map(|f| f.layout.words())
        .max()
        .unwrap()
        .max(1);
    let mut records = RecordPool::new(32, words, 8);
    let mut mem = Memory::new(module.globals_words());
    // scratch words so small pointer-valued args (nqueens' acc) are backed
    let _scratch = mem.alloc(8);
    let task = records.alloc(0, NO_TASK).unwrap();
    for (i, &a) in args.iter().enumerate() {
        records.data_mut(task)[i] = a as u64;
    }
    if state > 0 {
        // populate child results for continuation re-entries
        if let Some(off) = module.funcs[0].layout.result_offset() {
            for v in [1u64, 0] {
                let child = records.alloc(0, task).unwrap();
                records.push_child(task, child).unwrap();
                records.data_mut(child)[off as usize] = v;
                records.meta_mut(child).done = true;
            }
            records.meta_mut(task).pending_children = 0;
        }
    }
    let mut log = Vec::new();
    match tier {
        Tier::Ref => {
            let interp = RefInterp {
                module: &module,
                dev: &dev,
                block_width: 1,
                xla_payload: false,
            };
            let mut frame = RefLaneFrame::new();
            frame.reset(&module, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
        Tier::Decoded | Tier::Fused => {
            let interp = if tier == Tier::Fused {
                Interp::fused(&decoded, &fm, &dev, 1, false)
            } else {
                Interp::new(&decoded, &dev, 1, false)
            };
            let mut frame = LaneFrame::sized(&decoded);
            frame.reset(&decoded, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}

/// All three tiers must agree on end, cycles and spawns; decoded and fused
/// must agree on the path hash bit for bit.
fn assert_equivalent(src: &str, args: &[i64], state: u16) {
    let outs: Vec<_> = TIERS.iter().map(|&t| run_tier(src, args, state, t)).collect();
    let (r, d, f) = (&outs[0], &outs[1], &outs[2]);
    for (name, o) in [("decoded", d), ("fused", f)] {
        assert_eq!(
            o.0.end, r.0.end,
            "{name} segment end (args {args:?}, state {state})"
        );
        assert_eq!(
            o.0.cycles, r.0.cycles,
            "{name} cycle charge (args {args:?}, state {state})"
        );
        assert_eq!(o.1.len(), r.1.len(), "{name} spawn count");
        for (a, b) in o.1.iter().zip(r.1.iter()) {
            assert_eq!(a.func, b.func);
            assert_eq!(a.argc, b.argc);
            assert_eq!(a.queue, b.queue);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.args[..a.argc as usize], b.args[..b.argc as usize]);
        }
    }
    assert_eq!(
        d.0.path, f.0.path,
        "fused path hash must be bit-identical to decoded (args {args:?}, state {state})"
    );
}

#[test]
fn fib_segments_equivalent() {
    for n in [0i64, 1, 2, 5, 13, 30] {
        assert_equivalent(FIB, &[n], 0);
    }
    assert_equivalent(FIB, &[5], 1); // post-join continuation
}

#[test]
fn loop_and_intrinsic_segments_equivalent() {
    for n in [0i64, 1, 7, 100] {
        assert_equivalent(LOOPY, &[n], 0);
        assert_equivalent(INTRINSIC, &[n.max(1)], 0);
    }
}

#[test]
fn native_payload_segments_equivalent() {
    for s in [1i64, 42, 9999] {
        assert_equivalent(PAYLOAD, &[s], 0);
    }
}

#[test]
fn nqueens_segments_equivalent() {
    // spawn-in-loop segments with irregular spawn counts + the serial-leaf
    // intrinsic at the cutoff row
    let src = gtap::workloads::nqueens::source(3, true);
    let cases = [
        (0i64, [0u64; 3]),
        (2, [0b0110, 0b0001, 0b1000]),
        (3, [1, 2, 4]),
        (6, [0; 3]),
    ];
    for (row, masks) in cases {
        let args: Vec<i64> = vec![
            6,
            row,
            masks[0] as i64,
            masks[1] as i64,
            masks[2] as i64,
            0, // acc pointer: word 0 of the (global-free) memory
        ];
        assert_equivalent(&src, &args, 0);
    }
}

#[test]
fn tree_workload_segments_equivalent() {
    let src = gtap::workloads::tree::full_tree_source(16, 64);
    let module = compile_default(&src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module.funcs[0].layout.words().max(1);
    for (state, depth) in [(0u16, 4i64), (0, 0), (1, 3)] {
        let run = |tier: Tier| {
            let mut records = RecordPool::new(8, words, 4);
            let mut mem = Memory::new(module.globals_words());
            let acc = mem.alloc(1);
            let task = records.alloc(0, NO_TASK).unwrap();
            records.data_mut(task)[0] = depth as u64;
            records.data_mut(task)[1] = 7;
            records.data_mut(task)[2] = acc;
            let mut log = Vec::new();
            match tier {
                Tier::Ref => {
                    let interp = RefInterp {
                        module: &module,
                        dev: &dev,
                        block_width: 1,
                        xla_payload: false,
                    };
                    let mut frame = RefLaneFrame::new();
                    frame.reset(&module, task, 0, state, 0);
                    match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                        StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                        other => panic!("{other:?}"),
                    }
                }
                Tier::Decoded | Tier::Fused => {
                    let interp = if tier == Tier::Fused {
                        Interp::fused(&decoded, &fm, &dev, 1, false)
                    } else {
                        Interp::new(&decoded, &dev, 1, false)
                    };
                    let mut frame = LaneFrame::sized(&decoded);
                    frame.reset(&decoded, task, 0, state, 0);
                    match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                        StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                        other => panic!("{other:?}"),
                    }
                }
            }
        };
        let reference = run(Tier::Ref);
        assert_eq!(run(Tier::Decoded), reference, "decoded, state {state}, depth {depth}");
        assert_eq!(run(Tier::Fused), reference, "fused, state {state}, depth {depth}");
    }
}

#[test]
fn path_equality_structure_matches() {
    // Raw hashes differ between the reference (local pcs) and the
    // decoded/fused pair (global pcs), but lane grouping — the only thing
    // the divergence model reads — must coincide across all tiers: inputs
    // i, j land in the same group under one tier iff they do under every
    // other.
    let inputs: &[i64] = &[0, 1, 2, 3, 5, 8, 13, 1, 5, 0];
    let paths = |tier: Tier| -> Vec<u64> {
        inputs
            .iter()
            .map(|&n| run_tier(FIB, &[n], 0, tier).0.path)
            .collect()
    };
    let reference = paths(Tier::Ref);
    let decoded = paths(Tier::Decoded);
    let fused = paths(Tier::Fused);
    assert_eq!(decoded, fused, "decoded and fused hashes are bit-identical");
    for i in 0..inputs.len() {
        for j in 0..inputs.len() {
            assert_eq!(
                decoded[i] == decoded[j],
                reference[i] == reference[j],
                "grouping of inputs {} and {} diverged",
                inputs[i],
                inputs[j]
            );
        }
    }
}
