//! Differential testing: the decoded fast-path interpreter
//! (`sim::interp`) against the module-walking reference
//! (`sim::interp_ref`) on identical segment streams.
//!
//! For every program/input/state: same segment end, same simulated cycle
//! charge, same spawn list, and the same *path-equality structure* (the
//! two fold different pc encodings into the hash — function-local vs
//! global — so raw hash values legitimately differ; what the divergence
//! model consumes is only hash equality between lanes).

use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, SegmentOutput, SpawnReq, StepResult};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task queue(1)
        a = fib(n - 1);
        #pragma gtap task queue(1)
        b = fib(n - 2);
        #pragma gtap taskwait queue(2)
        return a + b;
    }
"#;

const LOOPY: &str = "#pragma gtap function\nint sum(int n) {\n\
                     int s = 0;\nfor (int i = 1; i <= n; i += 1) { s = s + i * i; }\n\
                     return s; }";

const INTRINSIC: &str = "#pragma gtap function\nint f(int n) { return fib_serial(n); }";

const PAYLOAD: &str = "#pragma gtap function\nfloat f(int s) { return payload(s, 8, 16); }";

/// Run one segment through both interpreters on identical fresh state;
/// returns (decoded, reference) outputs plus both spawn lists.
#[allow(clippy::type_complexity)]
fn run_both(
    src: &str,
    args: &[i64],
    state: u16,
) -> ((SegmentOutput, Vec<SpawnReq>), (SegmentOutput, Vec<SpawnReq>)) {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let words = module
        .funcs
        .iter()
        .map(|f| f.layout.words())
        .max()
        .unwrap()
        .max(1);

    let mut results = Vec::new();
    for which in 0..2 {
        let mut records = RecordPool::new(32, words, 8);
        let mut mem = Memory::new(module.globals_words());
        let task = records.alloc(0, NO_TASK).unwrap();
        for (i, &a) in args.iter().enumerate() {
            records.data_mut(task)[i] = a as u64;
        }
        if state > 0 {
            // populate child results for continuation re-entries
            if let Some(off) = module.funcs[0].layout.result_offset() {
                for v in [1u64, 0] {
                    let child = records.alloc(0, task).unwrap();
                    records.push_child(task, child).unwrap();
                    records.data_mut(child)[off as usize] = v;
                    records.meta_mut(child).done = true;
                }
                records.meta_mut(task).pending_children = 0;
            }
        }
        let mut log = Vec::new();
        let out = if which == 0 {
            let interp = Interp::new(&decoded, &dev, 1, false);
            let mut frame = LaneFrame::sized(&decoded);
            frame.reset(&decoded, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        } else {
            let interp = RefInterp {
                module: &module,
                dev: &dev,
                block_width: 1,
                xla_payload: false,
            };
            let mut frame = RefLaneFrame::new();
            frame.reset(&module, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        };
        results.push(out);
    }
    let reference = results.pop().unwrap();
    let fast = results.pop().unwrap();
    (fast, reference)
}

fn assert_equivalent(src: &str, args: &[i64], state: u16) {
    let ((fo, fs), (ro, rs)) = run_both(src, args, state);
    assert_eq!(fo.end, ro.end, "segment end (args {args:?}, state {state})");
    assert_eq!(
        fo.cycles, ro.cycles,
        "cycle charge (args {args:?}, state {state})"
    );
    assert_eq!(fs.len(), rs.len(), "spawn count");
    for (a, b) in fs.iter().zip(rs.iter()) {
        assert_eq!(a.func, b.func);
        assert_eq!(a.argc, b.argc);
        assert_eq!(a.queue, b.queue);
        assert_eq!(a.priority, b.priority);
        assert_eq!(a.args[..a.argc as usize], b.args[..b.argc as usize]);
    }
}

#[test]
fn fib_segments_equivalent() {
    for n in [0i64, 1, 2, 5, 13, 30] {
        assert_equivalent(FIB, &[n], 0);
    }
    assert_equivalent(FIB, &[5], 1); // post-join continuation
}

#[test]
fn loop_and_intrinsic_segments_equivalent() {
    for n in [0i64, 1, 7, 100] {
        assert_equivalent(LOOPY, &[n], 0);
        assert_equivalent(INTRINSIC, &[n.max(1)], 0);
    }
}

#[test]
fn native_payload_segments_equivalent() {
    for s in [1i64, 42, 9999] {
        assert_equivalent(PAYLOAD, &[s], 0);
    }
}

#[test]
fn tree_workload_segments_equivalent() {
    let src = gtap::workloads::tree::full_tree_source(16, 64);
    let module = compile_default(&src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let words = module.funcs[0].layout.words().max(1);
    for (state, depth) in [(0u16, 4i64), (0, 0), (1, 3)] {
        let run = |decoded_path: bool| {
            let mut records = RecordPool::new(8, words, 4);
            let mut mem = Memory::new(module.globals_words());
            let acc = mem.alloc(1);
            let task = records.alloc(0, NO_TASK).unwrap();
            records.data_mut(task)[0] = depth as u64;
            records.data_mut(task)[1] = 7;
            records.data_mut(task)[2] = acc;
            let mut log = Vec::new();
            if decoded_path {
                let interp = Interp::new(&decoded, &dev, 1, false);
                let mut frame = LaneFrame::sized(&decoded);
                frame.reset(&decoded, task, 0, state, 0);
                match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                    StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                    other => panic!("{other:?}"),
                }
            } else {
                let interp = RefInterp {
                    module: &module,
                    dev: &dev,
                    block_width: 1,
                    xla_payload: false,
                };
                let mut frame = RefLaneFrame::new();
                frame.reset(&module, task, 0, state, 0);
                match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                    StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                    other => panic!("{other:?}"),
                }
            }
        };
        assert_eq!(run(true), run(false), "state {state}, depth {depth}");
    }
}

#[test]
fn path_equality_structure_matches() {
    // hashes differ across interpreters (local vs global pc folding), but
    // lane grouping — the only thing the divergence model reads — must
    // coincide: inputs i, j land in the same group under the decoded
    // interpreter iff they do under the reference.
    let inputs: &[i64] = &[0, 1, 2, 3, 5, 8, 13, 1, 5, 0];
    let fast: Vec<u64> = inputs
        .iter()
        .map(|&n| run_both(FIB, &[n], 0).0 .0.path)
        .collect();
    let reference: Vec<u64> = inputs
        .iter()
        .map(|&n| run_both(FIB, &[n], 0).1 .0.path)
        .collect();
    for i in 0..inputs.len() {
        for j in 0..inputs.len() {
            assert_eq!(
                fast[i] == fast[j],
                reference[i] == reference[j],
                "grouping of inputs {} and {} diverged",
                inputs[i],
                inputs[j]
            );
        }
    }
}
