//! Differential testing across the four interpreter tiers on identical
//! segment streams:
//!
//! * `sim::interp_ref` — the module-walking **reference**;
//! * `sim::interp` over `ir::decoded` — flattened per-instruction
//!   **decoded** dispatch;
//! * `Interp::fused` over `ir::superblock` — block-at-a-time **fused**
//!   dispatch with folded costs and macro-ops;
//! * `Interp::traced` over `ir::traced` — trace-at-a-time **traced**
//!   dispatch across biased branches with scratch-demoted registers and
//!   side exits (the production engine).
//!
//! For every program/input/state: same segment end, same simulated cycle
//! charge, same spawn list across all four. Path hashes are
//! **bit-identical between decoded, fused and traced** (all fold global
//! pcs; the superblock/trace invariant). The reference folds
//! *function-local* pcs, so its raw hash values legitimately differ;
//! against it only the *path-equality structure* — the sole thing the
//! divergence model consumes — must coincide.
//!
//! Both memory-system modes are covered: under the flat default the
//! access streams are empty and the charges are the pre-memsys pins;
//! under `--memsys modeled` (recording interpreters) the **access
//! streams** are functional data and must be bit-identical across all
//! four tiers — that is what lets the warp-combine cost model charge
//! once, independent of dispatch tier (`sim::memsys`).
//!
//! The traced tier is additionally exercised with an **inverted branch
//! profile** (every biased branch predicted against the real hot path),
//! which forces side-exit-heavy traces — the cost-transparency invariant
//! must survive mispredicted dispatch too.

mod common;

use common::{
    bfs_setup, inverted_profile_for, msort_setup, run_mem_workload_tier,
    run_mem_workload_tier_profiled, Tier, TIERS,
};
use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::ir::traced::TracedModule;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::memsys::MemAccess;
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, SegmentOutput, SpawnReq, StepResult};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task queue(1)
        a = fib(n - 1);
        #pragma gtap task queue(1)
        b = fib(n - 2);
        #pragma gtap taskwait queue(2)
        return a + b;
    }
"#;

const LOOPY: &str = "#pragma gtap function\nint sum(int n) {\n\
                     int s = 0;\nfor (int i = 1; i <= n; i += 1) { s = s + i * i; }\n\
                     return s; }";

const INTRINSIC: &str = "#pragma gtap function\nint f(int n) { return fib_serial(n); }";

const PAYLOAD: &str = "#pragma gtap function\nfloat f(int s) { return payload(s, 8, 16); }";

/// Run one segment through one tier on identical fresh state. `modeled`
/// selects the recording interpreters (`--memsys modeled` gating).
fn run_tier_mode(
    src: &str,
    args: &[i64],
    state: u16,
    tier: Tier,
    modeled: bool,
) -> (SegmentOutput, Vec<SpawnReq>, Vec<MemAccess>) {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module
        .funcs
        .iter()
        .map(|f| f.layout.words())
        .max()
        .unwrap()
        .max(1);
    let mut records = RecordPool::new(32, words, 8);
    let mut mem = Memory::new(module.globals_words());
    // scratch words so small pointer-valued args (nqueens' acc) are backed
    let _scratch = mem.alloc(8);
    let task = records.alloc(0, NO_TASK).unwrap();
    for (i, &a) in args.iter().enumerate() {
        records.data_mut(task)[i] = a as u64;
    }
    if state > 0 {
        // populate child results for continuation re-entries
        if let Some(off) = module.funcs[0].layout.result_offset() {
            for v in [1u64, 0] {
                let child = records.alloc(0, task).unwrap();
                records.push_child(task, child).unwrap();
                records.data_mut(child)[off as usize] = v;
                records.meta_mut(child).done = true;
            }
            records.meta_mut(task).pending_children = 0;
        }
    }
    let mut log = Vec::new();
    match tier {
        Tier::Ref => {
            let interp = RefInterp {
                module: &module,
                dev: &dev,
                block_width: 1,
                xla_payload: false,
                record_accesses: modeled,
            };
            let mut frame = RefLaneFrame::new();
            frame.reset(&module, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec(), frame.accesses().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
        Tier::Decoded | Tier::Fused | Tier::Traced => {
            let tm;
            let base = match tier {
                Tier::Fused => Interp::fused(&decoded, &fm, &dev, 1, false),
                Tier::Traced => {
                    tm = TracedModule::build(&decoded, &fm, &dev, None);
                    Interp::traced(&decoded, &tm, &dev, 1, false)
                }
                _ => Interp::new(&decoded, &dev, 1, false),
            };
            let interp = base.recording(modeled);
            let mut frame = LaneFrame::sized(&decoded);
            frame.reset(&decoded, task, 0, state, 0);
            match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                StepResult::Done(o) => (o, frame.spawns().to_vec(), frame.accesses().to_vec()),
                other => panic!("unexpected {other:?}"),
            }
        }
    }
}

fn run_tier(src: &str, args: &[i64], state: u16, tier: Tier) -> (SegmentOutput, Vec<SpawnReq>) {
    let (o, s, _) = run_tier_mode(src, args, state, tier, false);
    (o, s)
}

/// All four tiers must agree on end, cycles and spawns; decoded, fused
/// and traced must agree on the path hash bit for bit. Under the modeled
/// memory system the access streams must additionally be bit-identical
/// across all four tiers (they are the cost model's input); under the
/// flat default they must be empty.
fn assert_equivalent_mode(src: &str, args: &[i64], state: u16, modeled: bool) {
    let outs: Vec<_> = TIERS
        .iter()
        .map(|&t| run_tier_mode(src, args, state, t, modeled))
        .collect();
    let (r, d, f, t) = (&outs[0], &outs[1], &outs[2], &outs[3]);
    for (name, o) in [("decoded", d), ("fused", f), ("traced", t)] {
        assert_eq!(
            o.0.end, r.0.end,
            "{name} segment end (args {args:?}, state {state}, modeled {modeled})"
        );
        assert_eq!(
            o.0.cycles, r.0.cycles,
            "{name} cycle charge (args {args:?}, state {state}, modeled {modeled})"
        );
        assert_eq!(o.1.len(), r.1.len(), "{name} spawn count");
        for (a, b) in o.1.iter().zip(r.1.iter()) {
            assert_eq!(a.func, b.func);
            assert_eq!(a.argc, b.argc);
            assert_eq!(a.queue, b.queue);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.args[..a.argc as usize], b.args[..b.argc as usize]);
        }
        assert_eq!(
            o.2, r.2,
            "{name} access stream (args {args:?}, state {state}, modeled {modeled})"
        );
    }
    if !modeled {
        assert!(r.2.is_empty(), "flat mode must record nothing");
    }
    assert_eq!(
        d.0.path, f.0.path,
        "fused path hash must be bit-identical to decoded (args {args:?}, state {state})"
    );
    assert_eq!(
        d.0.path, t.0.path,
        "traced path hash must be bit-identical to decoded (args {args:?}, state {state})"
    );
}

fn assert_equivalent(src: &str, args: &[i64], state: u16) {
    assert_equivalent_mode(src, args, state, false);
}

#[test]
fn fib_segments_equivalent() {
    for n in [0i64, 1, 2, 5, 13, 30] {
        assert_equivalent(FIB, &[n], 0);
    }
    assert_equivalent(FIB, &[5], 1); // post-join continuation
}

#[test]
fn loop_and_intrinsic_segments_equivalent() {
    for n in [0i64, 1, 7, 100] {
        assert_equivalent(LOOPY, &[n], 0);
        assert_equivalent(INTRINSIC, &[n.max(1)], 0);
    }
}

#[test]
fn native_payload_segments_equivalent() {
    for s in [1i64, 42, 9999] {
        assert_equivalent(PAYLOAD, &[s], 0);
    }
}

#[test]
fn nqueens_segments_equivalent() {
    // spawn-in-loop segments with irregular spawn counts + the serial-leaf
    // intrinsic at the cutoff row
    let src = gtap::workloads::nqueens::source(3, true);
    let cases = [
        (0i64, [0u64; 3]),
        (2, [0b0110, 0b0001, 0b1000]),
        (3, [1, 2, 4]),
        (6, [0; 3]),
    ];
    for (row, masks) in cases {
        let args: Vec<i64> = vec![
            6,
            row,
            masks[0] as i64,
            masks[1] as i64,
            masks[2] as i64,
            0, // acc pointer: word 0 of the (global-free) memory
        ];
        assert_equivalent(&src, &args, 0);
    }
}

#[test]
fn tree_workload_segments_equivalent() {
    let src = gtap::workloads::tree::full_tree_source(16, 64);
    let module = compile_default(&src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module.funcs[0].layout.words().max(1);
    for (state, depth) in [(0u16, 4i64), (0, 0), (1, 3)] {
        let run = |tier: Tier| {
            let mut records = RecordPool::new(8, words, 4);
            let mut mem = Memory::new(module.globals_words());
            let acc = mem.alloc(1);
            let task = records.alloc(0, NO_TASK).unwrap();
            records.data_mut(task)[0] = depth as u64;
            records.data_mut(task)[1] = 7;
            records.data_mut(task)[2] = acc;
            let mut log = Vec::new();
            match tier {
                Tier::Ref => {
                    let interp = RefInterp {
                        module: &module,
                        dev: &dev,
                        block_width: 1,
                        xla_payload: false,
                        record_accesses: false,
                    };
                    let mut frame = RefLaneFrame::new();
                    frame.reset(&module, task, 0, state, 0);
                    match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                        StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                        other => panic!("{other:?}"),
                    }
                }
                Tier::Decoded | Tier::Fused | Tier::Traced => {
                    let tm;
                    let interp = match tier {
                        Tier::Fused => Interp::fused(&decoded, &fm, &dev, 1, false),
                        Tier::Traced => {
                            tm = TracedModule::build(&decoded, &fm, &dev, None);
                            Interp::traced(&decoded, &tm, &dev, 1, false)
                        }
                        _ => Interp::new(&decoded, &dev, 1, false),
                    };
                    let mut frame = LaneFrame::sized(&decoded);
                    frame.reset(&decoded, task, 0, state, 0);
                    match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
                        StepResult::Done(o) => (o.cycles, frame.spawns().len(), mem.load(acc)),
                        other => panic!("{other:?}"),
                    }
                }
            }
        };
        let reference = run(Tier::Ref);
        assert_eq!(run(Tier::Decoded), reference, "decoded, state {state}, depth {depth}");
        assert_eq!(run(Tier::Fused), reference, "fused, state {state}, depth {depth}");
        assert_eq!(run(Tier::Traced), reference, "traced, state {state}, depth {depth}");
    }
}

#[test]
fn bfs_segments_equivalent() {
    // BFS (Program 5): parallel_for over a CSR row, atomic_min relaxation,
    // spawn-per-improved-neighbour — the pointer-heavy irregular segment
    // family the original tier suite was missing. Both memsys modes.
    let src = gtap::workloads::bfs::source();
    let g = gtap::workloads::bfs::CsrGraph::random(12, 2, 3);
    for modeled in [false, true] {
        for v in [0i64, 5, 11] {
            let setup = bfs_setup(&g, v);
            let r = run_mem_workload_tier(&src, 0, Tier::Ref, modeled, 64, &setup);
            let d = run_mem_workload_tier(&src, 0, Tier::Decoded, modeled, 64, &setup);
            let f = run_mem_workload_tier(&src, 0, Tier::Fused, modeled, 64, &setup);
            let t = run_mem_workload_tier(&src, 0, Tier::Traced, modeled, 64, &setup);
            // the reference folds local pcs, so only the functional tuple
            // (cycles/spawns/streams/memory) is comparable against it
            assert_eq!(d.functional(), r.functional(), "decoded bfs (v {v}, modeled {modeled})");
            assert_eq!(f.functional(), r.functional(), "fused bfs (v {v}, modeled {modeled})");
            assert_eq!(t.functional(), r.functional(), "traced bfs (v {v}, modeled {modeled})");
            assert_eq!(d.path, f.path, "decoded/fused path hashes (v {v})");
            assert_eq!(d.path, t.path, "decoded/traced path hashes (v {v})");
            if modeled {
                assert!(
                    !r.accesses.is_empty(),
                    "bfs reads CSR rows — stream must record them"
                );
            }
        }
    }
}

#[test]
fn mergesort_segments_equivalent() {
    // Mergesort (§6.2): serial-sort leaf, spawning split, and the
    // merge_serial + memcpy continuation — the array-walking segment
    // family. Both memsys modes.
    let src = gtap::workloads::sort::mergesort_source(8);
    let n = 24usize;
    let xs = gtap::workloads::sort::input(n, 5);
    for modeled in [false, true] {
        // (state, left, right): leaf / split / post-join merge
        for &(state, left, right) in &[(0u16, 0i64, 8i64), (0, 0, 24), (1, 0, 24)] {
            let setup = msort_setup(&xs, state, left, right);
            let r = run_mem_workload_tier(&src, state, Tier::Ref, modeled, 1, &setup);
            let d = run_mem_workload_tier(&src, state, Tier::Decoded, modeled, 1, &setup);
            let f = run_mem_workload_tier(&src, state, Tier::Fused, modeled, 1, &setup);
            let t = run_mem_workload_tier(&src, state, Tier::Traced, modeled, 1, &setup);
            assert_eq!(
                d.functional(),
                r.functional(),
                "decoded msort (state {state}, modeled {modeled})"
            );
            assert_eq!(
                f.functional(),
                r.functional(),
                "fused msort (state {state}, modeled {modeled})"
            );
            assert_eq!(
                t.functional(),
                r.functional(),
                "traced msort (state {state}, modeled {modeled})"
            );
            assert_eq!(d.path, f.path, "decoded/fused path hashes (state {state})");
            assert_eq!(d.path, t.path, "decoded/traced path hashes (state {state})");
            if state == 0 && right - left > 8 {
                assert_eq!(r.spawns, 2, "the split segment spawns both halves");
            }
            if modeled && state == 1 {
                // the post-join merge is intrinsic-dominated: its
                // merge_serial/memcpy payload traffic must be in the
                // stream (priced by the transaction model, not exempt)
                assert!(
                    r.accesses.len() >= 2 * (right - left) as usize,
                    "intrinsic traffic recorded: {} records",
                    r.accesses.len()
                );
            }
        }
    }
}

#[test]
fn traced_side_exit_heavy_segments_equivalent() {
    // Build the traced tier with an *inverted* branch profile — every
    // biased branch predicted against the segment's real hot path — so
    // traces side-exit on nearly every dispatch. The cost-transparency
    // invariant (cycles, spawns, streams, memory image, path hash) must
    // hold regardless of prediction quality. Both memsys modes.
    let src = gtap::workloads::sort::mergesort_source(8);
    let xs = gtap::workloads::sort::input(24, 5);
    for modeled in [false, true] {
        for &(state, left, right) in &[(0u16, 0i64, 24i64), (1, 0, 24)] {
            let setup = msort_setup(&xs, state, left, right);
            let anti = inverted_profile_for(&src, state, 1, &setup);
            let d = run_mem_workload_tier(&src, state, Tier::Decoded, modeled, 1, &setup);
            let t = run_mem_workload_tier_profiled(
                &src,
                state,
                Tier::Traced,
                modeled,
                1,
                Some(&anti),
                &setup,
            );
            assert_eq!(
                t.functional(),
                d.functional(),
                "anti-profiled traced msort (state {state}, modeled {modeled})"
            );
            assert_eq!(
                t.path, d.path,
                "anti-profiled traced path hash (state {state}, modeled {modeled})"
            );
        }
    }
}

#[test]
fn modeled_memsys_segments_equivalent() {
    // the acceptance pin: under --memsys modeled all four tiers still
    // produce identical SegmentOutputs — and identical access streams
    for n in [0i64, 1, 5, 13] {
        assert_equivalent_mode(FIB, &[n], 0, true);
    }
    assert_equivalent_mode(FIB, &[5], 1, true);
    for n in [0i64, 7, 100] {
        assert_equivalent_mode(LOOPY, &[n], 0, true);
        assert_equivalent_mode(INTRINSIC, &[n.max(1)], 0, true);
    }
    let src = gtap::workloads::nqueens::source(3, true);
    assert_equivalent_mode(&src, &[6, 2, 0b0110, 0b0001, 0b1000, 0], 0, true);
}

#[test]
fn modeled_streams_record_global_and_td_traffic() {
    use gtap::sim::memsys::AccessKind;
    let src = "global int g;\n#pragma gtap function\nint f(int n) { g = g + n; return g; }";
    let (_, _, acc) = run_tier_mode(src, &[3], 0, Tier::Fused, true);
    assert!(acc.iter().any(|a| a.kind == AccessKind::GlobalLoad), "{acc:?}");
    assert!(acc.iter().any(|a| a.kind == AccessKind::GlobalStore), "{acc:?}");
    assert!(acc.iter().any(|a| a.kind == AccessKind::TdLoad), "arg read: {acc:?}");
    assert!(acc.iter().any(|a| a.kind == AccessKind::TdStore), "result store: {acc:?}");
}

#[test]
fn path_equality_structure_matches() {
    // Raw hashes differ between the reference (local pcs) and the
    // decoded/fused pair (global pcs), but lane grouping — the only thing
    // the divergence model reads — must coincide across all tiers: inputs
    // i, j land in the same group under one tier iff they do under every
    // other.
    let inputs: &[i64] = &[0, 1, 2, 3, 5, 8, 13, 1, 5, 0];
    let paths = |tier: Tier| -> Vec<u64> {
        inputs
            .iter()
            .map(|&n| run_tier(FIB, &[n], 0, tier).0.path)
            .collect()
    };
    let reference = paths(Tier::Ref);
    let decoded = paths(Tier::Decoded);
    let fused = paths(Tier::Fused);
    let traced = paths(Tier::Traced);
    assert_eq!(decoded, fused, "decoded and fused hashes are bit-identical");
    assert_eq!(decoded, traced, "decoded and traced hashes are bit-identical");
    for i in 0..inputs.len() {
        for j in 0..inputs.len() {
            assert_eq!(
                decoded[i] == decoded[j],
                reference[i] == reference[j],
                "grouping of inputs {} and {} diverged",
                inputs[i],
                inputs[j]
            );
        }
    }
}
