//! Contract suite for the multi-tenant service engine
//! (`runtime::service`): single-tenant transparency against one-shot
//! `Session::run`, shared lowering across sessions, admission-policy
//! behaviour, replay determinism, cancellation, deadline eviction with
//! co-tenant isolation, and per-tenant accounting reconciliation.

use gtap::coordinator::{Granularity, GtapConfig, Session};
use gtap::ir::types::Value;
use gtap::runtime::service::{
    AdmissionPolicy, CancelToken, JobOutcome, JobStatus, ServiceEngine, SubmitOpts,
};
use gtap::sim::DeviceSpec;
use gtap::workloads::{fib, tree};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

const ACCUM: &str = r#"
    global int g_sum;
    #pragma gtap function
    void add(int n) { g_sum = g_sum + n; }
"#;

fn cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 32,
        ..Default::default()
    }
}

fn engine(adm: AdmissionPolicy) -> ServiceEngine {
    ServiceEngine::new(cfg(), DeviceSpec::h100(), adm).unwrap()
}

#[test]
fn single_tenant_service_is_byte_identical_to_session_run() {
    let mut sess = Session::compile(FIB, cfg(), DeviceSpec::h100()).unwrap();
    let base = sess.run("fib", &[Value::from_i64(12)]).unwrap();

    let mut eng = engine(AdmissionPolicy::Fifo);
    let t = eng.open_session("solo", FIB).unwrap();
    eng.submit(t, "fib", &[Value::from_i64(12)], SubmitOpts::default())
        .unwrap();
    eng.submit(t, "fib", &[Value::from_i64(12)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 2);
    for o in &outs {
        assert_eq!(o.status, JobStatus::Completed);
        // the whole round's fleet stats — cycles included — match the
        // one-shot session run, byte for byte
        assert_eq!(o.fleet, base, "service round != Session::run");
        assert_eq!(o.result, base.root_result);
        assert_eq!(o.stats.tasks_finished, base.tasks_finished);
        assert_eq!(o.stats.spawns, base.spawns);
        assert_eq!(o.stats.segments, base.segments);
        assert!(!o.stats.evicted);
    }
    assert_eq!(eng.rounds(), 2, "FIFO serves one job per round");
    assert_eq!(eng.virtual_cycles(), 2 * base.cycles);
}

#[test]
fn sessions_with_equal_content_share_one_lowering() {
    let mut eng = engine(AdmissionPolicy::FairShare);
    let a = eng.open_session("a", FIB).unwrap();
    let b = eng.open_session("b", FIB).unwrap();
    assert_eq!(eng.cache_stats(), (1, 1));
    eng.submit(a, "fib", &[Value::from_i64(11)], SubmitOpts::default())
        .unwrap();
    eng.submit(b, "fib", &[Value::from_i64(10)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    assert_eq!(eng.cache_stats(), (1, 1), "rounds never touch the cache");
    let outs = eng.take_outcomes();
    assert_eq!(outs[0].result.unwrap().as_i64(), fib::reference(11));
    assert_eq!(outs[1].result.unwrap().as_i64(), fib::reference(10));
}

#[test]
fn fair_share_coschedules_while_fifo_serializes() {
    let schedule = |adm: AdmissionPolicy| -> (u64, Vec<JobOutcome>) {
        let mut eng = engine(adm);
        let a = eng.open_session("a", FIB).unwrap();
        let b = eng.open_session("b", FIB).unwrap();
        for _ in 0..2 {
            eng.submit(a, "fib", &[Value::from_i64(11)], SubmitOpts::default())
                .unwrap();
            eng.submit(b, "fib", &[Value::from_i64(9)], SubmitOpts::default())
                .unwrap();
        }
        eng.run_to_idle().unwrap();
        (eng.rounds(), eng.take_outcomes())
    };
    let (fifo_rounds, fifo_outs) = schedule(AdmissionPolicy::Fifo);
    let (fair_rounds, fair_outs) = schedule(AdmissionPolicy::FairShare);
    assert_eq!(fifo_rounds, 4, "FIFO: one job per round");
    assert_eq!(fair_rounds, 2, "fair share: both tenants per round");
    for o in fifo_outs.iter().chain(fair_outs.iter()) {
        assert_eq!(o.status, JobStatus::Completed);
    }
    // co-scheduling changes packing, not results
    let val = |outs: &[JobOutcome], t| {
        outs.iter()
            .filter(|o| o.tenant == t)
            .map(|o| o.result.unwrap().as_i64())
            .collect::<Vec<_>>()
    };
    assert_eq!(val(&fifo_outs, 0), val(&fair_outs, 0));
    assert_eq!(val(&fifo_outs, 1), val(&fair_outs, 1));
}

#[test]
fn priority_weighted_admission_orders_slots_by_urgency() {
    let mut eng = engine(AdmissionPolicy::PriorityWeighted);
    let a = eng.open_session("bulk", FIB).unwrap();
    let b = eng.open_session("urgent", FIB).unwrap();
    let opts = |p: u8| SubmitOpts {
        priority: p,
        ..Default::default()
    };
    let ja = eng.submit(a, "fib", &[Value::from_i64(10)], opts(3)).unwrap();
    let jb = eng.submit(b, "fib", &[Value::from_i64(10)], opts(0)).unwrap();
    assert!(eng.run_round().unwrap());
    let outs = eng.take_outcomes();
    // one round, both jobs; the urgent job owns slot 0 despite being
    // submitted later
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].job, jb);
    assert_eq!(outs[1].job, ja);
    assert_eq!(eng.pending_jobs(), 0);
}

#[test]
fn identical_submission_schedules_replay_byte_identically() {
    let run = || -> Vec<JobOutcome> {
        let mut eng = engine(AdmissionPolicy::FairShare);
        let a = eng.open_session("a", FIB).unwrap();
        let b = eng.open_session("b", ACCUM).unwrap();
        eng.submit(a, "fib", &[Value::from_i64(12)], SubmitOpts::default())
            .unwrap();
        eng.submit(b, "add", &[Value::from_i64(5)], SubmitOpts::default())
            .unwrap();
        eng.submit(a, "fib", &[Value::from_i64(10)], SubmitOpts::default())
            .unwrap();
        eng.run_to_idle().unwrap();
        eng.take_outcomes()
    };
    assert_eq!(run(), run(), "same schedule, same outcomes, byte for byte");
}

#[test]
fn pending_cancellation_never_touches_the_device() {
    let mut eng = engine(AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    let token = CancelToken::new();
    eng.submit(t, "fib", &[Value::from_i64(10)], SubmitOpts::default())
        .unwrap();
    let cancelled = eng
        .submit(
            t,
            "fib",
            &[Value::from_i64(20)],
            SubmitOpts {
                cancel: Some(token.clone()),
                ..Default::default()
            },
        )
        .unwrap();
    token.cancel();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 2);
    let c = outs.iter().find(|o| o.job == cancelled).unwrap();
    assert_eq!(c.status, JobStatus::Cancelled);
    assert_eq!(c.stats.tasks_finished, 0);
    assert_eq!(c.result, None);
    assert_eq!(eng.rounds(), 1, "the cancelled job never got a round");
    assert_eq!(eng.accounting(t).jobs_cancelled, 1);
    assert_eq!(eng.accounting(t).jobs_completed, 1);
}

#[test]
fn deadline_evicts_only_the_deadlined_tenant() {
    // Solo baseline for the surviving tenant.
    let mut sess = Session::compile(FIB, cfg(), DeviceSpec::h100()).unwrap();
    let solo = sess.run("fib", &[Value::from_i64(12)]).unwrap();

    let mut eng = engine(AdmissionPolicy::FairShare);
    let keep = eng.open_session("keep", FIB).unwrap();
    let evict = eng.open_session("evict", FIB).unwrap();
    eng.submit(keep, "fib", &[Value::from_i64(12)], SubmitOpts::default())
        .unwrap();
    // deadline below dev.startup → evicted at the first event, before
    // any task executes
    eng.submit(
        evict,
        "fib",
        &[Value::from_i64(20)],
        SubmitOpts {
            deadline: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 2);
    let k = outs.iter().find(|o| o.tenant == keep).unwrap();
    let e = outs.iter().find(|o| o.tenant == evict).unwrap();
    assert_eq!(e.status, JobStatus::Evicted);
    assert!(e.stats.evicted);
    assert_eq!(e.stats.tasks_finished, 0);
    assert_eq!(e.result, None);
    // the co-tenant is untouched: results and task counts pin to solo
    assert_eq!(k.status, JobStatus::Completed);
    assert_eq!(k.result, solo.root_result);
    assert_eq!(k.stats.tasks_finished, solo.tasks_finished);
    assert_eq!(k.stats.spawns, solo.spawns);
    assert_eq!(eng.accounting(evict).jobs_evicted, 1);
    assert_eq!(eng.accounting(keep).jobs_completed, 1);
}

#[test]
fn sole_cancelled_job_resolves_without_a_round() {
    let mut eng = engine(AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    let token = CancelToken::new();
    let job = eng
        .submit(
            t,
            "fib",
            &[Value::from_i64(15)],
            SubmitOpts {
                cancel: Some(token.clone()),
                ..Default::default()
            },
        )
        .unwrap();
    // cancellation resolves at the next round boundary's sweep; with
    // nothing else pending, no round runs at all
    token.cancel();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].job, job);
    assert_eq!(outs[0].status, JobStatus::Cancelled);
    assert_eq!(outs[0].stats.tasks_finished, 0);
    assert_eq!(eng.rounds(), 0, "cancelled work never touches the device");
}

#[test]
fn per_tenant_stats_reconcile_with_the_fleet() {
    let mut eng = engine(AdmissionPolicy::FairShare);
    let a = eng.open_session("a", FIB).unwrap();
    let b = eng.open_session("b", FIB).unwrap();
    eng.submit(a, "fib", &[Value::from_i64(12)], SubmitOpts::default())
        .unwrap();
    eng.submit(b, "fib", &[Value::from_i64(10)], SubmitOpts::default())
        .unwrap();
    assert!(eng.run_round().unwrap());
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 2, "one co-scheduled round");
    assert_eq!(outs[0].fleet, outs[1].fleet, "same round, same fleet view");
    let fleet = &outs[0].fleet;
    let sum = |f: fn(&gtap::coordinator::TenantStats) -> u64| -> u64 {
        outs.iter().map(|o| f(&o.stats)).sum()
    };
    assert_eq!(sum(|s| s.tasks_finished), fleet.tasks_finished);
    assert_eq!(sum(|s| s.spawns), fleet.spawns);
    assert_eq!(sum(|s| s.segments), fleet.segments);
    // each tenant's slice is its solo task tree
    for (t, n) in [(a, 12i64), (b, 10i64)] {
        let o = outs.iter().find(|o| o.tenant == t).unwrap();
        assert_eq!(o.result.unwrap().as_i64(), fib::reference(n));
        assert!(o.stats.completed_at.is_some());
        assert!(o.stats.completed_at.unwrap() <= fleet.cycles);
    }
}

#[test]
fn tenant_memory_persists_across_jobs_and_is_isolated() {
    let mut eng = engine(AdmissionPolicy::Fifo);
    let a = eng.open_session("a", ACCUM).unwrap();
    let b = eng.open_session("b", ACCUM).unwrap();
    eng.submit(a, "add", &[Value::from_i64(5)], SubmitOpts::default())
        .unwrap();
    eng.submit(a, "add", &[Value::from_i64(7)], SubmitOpts::default())
        .unwrap();
    eng.submit(b, "add", &[Value::from_i64(100)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    // a's global accumulated across two jobs; b's memory is its own
    assert_eq!(eng.get_global(a, "g_sum").unwrap().as_i64(), 12);
    assert_eq!(eng.get_global(b, "g_sum").unwrap().as_i64(), 100);
}

#[test]
fn block_granularity_mixed_workload_round() {
    let mem_ops = 4i64;
    let compute_iters = 4i64;
    let block = 64usize;
    let cfg = GtapConfig {
        grid_size: 4,
        block_size: block,
        granularity: Granularity::Block,
        ..Default::default()
    };
    let tree_src = tree::full_tree_block_source(mem_ops, compute_iters, block as i64);
    let mut eng =
        ServiceEngine::new(cfg, DeviceSpec::h100(), AdmissionPolicy::FairShare).unwrap();
    let tf = eng.open_session("fib", FIB).unwrap();
    let tt = eng.open_session("tree", &tree_src).unwrap();
    let acc = eng.memory_mut(tt).alloc(1);
    eng.submit(tf, "fib", &[Value::from_i64(10)], SubmitOpts::default())
        .unwrap();
    eng.submit(
        tt,
        "tree",
        &[Value::from_i64(4), Value::from_i64(7), Value(acc)],
        SubmitOpts::default(),
    )
    .unwrap();
    eng.run_to_idle().unwrap();
    assert_eq!(eng.rounds(), 1, "one co-scheduled block-level round");
    let outs = eng.take_outcomes();
    let f = outs.iter().find(|o| o.tenant == tf).unwrap();
    assert_eq!(f.result.unwrap().as_i64(), fib::reference(10));
    let want =
        tree::full_tree_block_reference(4, 7, mem_ops, compute_iters, block as i64);
    assert_eq!(eng.memory(tt).read_i64s(acc, 1), vec![want]);
}

#[test]
fn submission_validation_fails_at_the_api_edge() {
    let mut eng = engine(AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    assert!(eng.submit(t, "nope", &[], SubmitOpts::default()).is_err());
    assert!(eng.submit(t, "fib", &[], SubmitOpts::default()).is_err());
    assert!(eng
        .submit(99, "fib", &[Value::from_i64(1)], SubmitOpts::default())
        .is_err());
    assert_eq!(eng.pending_jobs(), 0);
}
