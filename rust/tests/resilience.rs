//! Contract suite for the service resilience layer
//! (`runtime::service::resilience` + engine wiring): typed job errors,
//! retry-until-success under seeded fault plans (byte-identical results to
//! fault-free baselines), tenant quarantine with co-tenant isolation,
//! deterministic overload backpressure/shedding, and checkpointed retries
//! that re-execute nothing.

use gtap::bench::sweep;
use gtap::coordinator::{EvictCause, FaultPlan, GtapConfig, Session};
use gtap::ir::types::Value;
use gtap::runtime::service::{
    AdmissionPolicy, CancelToken, JobError, JobOutcome, JobStatus, ResilienceConfig,
    ServiceEngine, SubmitOpts, SubmitResult,
};
use gtap::sim::DeviceSpec;
use gtap::util::error::ErrorKind;
use gtap::workloads::fib;

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

const ACCUM: &str = r#"
    global int g_sum;
    #pragma gtap function
    void add(int n) { g_sum = g_sum + n; }
"#;

fn cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 32,
        ..Default::default()
    }
}

fn cfg_with_faults(spec: &str) -> GtapConfig {
    let mut c = cfg();
    c.faults = FaultPlan::parse(spec).unwrap();
    c
}

fn engine(c: GtapConfig, adm: AdmissionPolicy) -> ServiceEngine {
    ServiceEngine::new(c, DeviceSpec::h100(), adm).unwrap()
}

/// Retry policy used by the fault-sweep tests: generous budgets, small
/// backoff (the backoff value only moves the virtual clock).
fn retry_config() -> ResilienceConfig {
    ResilienceConfig {
        retry: true,
        max_retries: 16,
        retry_budget: 64,
        backoff_base: 1 << 8,
        ..Default::default()
    }
}

/// The three-tenant mix every fault plan is replayed against: two pure
/// fib tenants plus a global-accumulating tenant (side effects must stay
/// exactly-once under checkpointed retries). Returns the terminal
/// `(job, tenant, status, result)` tuples plus the accumulator value.
fn run_mix(c: GtapConfig, resil: ResilienceConfig) -> (Vec<(u64, u16, JobStatus, Option<Value>)>, i64) {
    let mut eng = engine(c, AdmissionPolicy::FairShare);
    eng.set_resilience(resil);
    let a = eng.open_session("fib-a", FIB).unwrap();
    let b = eng.open_session("fib-b", FIB).unwrap();
    let s = eng.open_session("accum", ACCUM).unwrap();
    for n in [11i64, 10, 11] {
        eng.submit(a, "fib", &[Value::from_i64(n)], SubmitOpts::default())
            .unwrap();
        eng.submit(b, "fib", &[Value::from_i64(n - 2)], SubmitOpts::default())
            .unwrap();
        eng.submit(s, "add", &[Value::from_i64(n)], SubmitOpts::default())
            .unwrap();
    }
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    let mut tuples: Vec<_> = outs
        .iter()
        .map(|o| (o.job, o.tenant, o.status, o.result))
        .collect();
    tuples.sort_by_key(|t| t.0);
    let g = eng.get_global(s, "g_sum").unwrap().as_i64();
    (tuples, g)
}

#[test]
fn retry_under_every_fault_plan_matches_the_fault_free_baseline() {
    let baseline = run_mix(cfg(), retry_config());
    for (_, _, status, _) in &baseline.0 {
        assert_eq!(*status, JobStatus::Completed);
    }
    assert_eq!(baseline.1, 11 + 10 + 11, "accumulator exactly-once");

    // Named single-fault specs composed with a fault-plane deadline that
    // drains live work (startup is 50k cycles, so deadline@60000 leaves a
    // thin slice per round — the engine escalates it on every drained
    // round until the mix finishes), plus 8 seeded rand: compositions.
    let mut specs: Vec<String> = [
        "deadline@60000",
        "stall@55000:w1:4000;deadline@60000",
        "kill@55000:w2;deadline@60000",
        "stealfail@55000:w0:8;deadline@60000",
        "drop@55000:w3:q0;deadline@60000",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    specs.extend((1..=8).map(|s| format!("rand:{s};deadline@60000")));

    for spec in &specs {
        let faulty = run_mix(cfg_with_faults(spec), retry_config());
        assert_eq!(
            faulty.0, baseline.0,
            "outcomes diverge from the fault-free baseline under {spec:?}"
        );
        assert_eq!(
            faulty.1, baseline.1,
            "accumulator not exactly-once under {spec:?}"
        );
    }
}

#[test]
fn pure_rand_plans_recover_in_run_without_retries() {
    // Seeded rand: plans contain no deadline — the scheduler self-heals
    // (watchdog + recovery), so jobs complete on the first attempt and
    // the retry layer stays idle even when armed.
    let baseline = run_mix(cfg(), retry_config());
    for seed in [3u64, 17, 99] {
        let faulty = run_mix(cfg_with_faults(&format!("rand:{seed}")), retry_config());
        assert_eq!(faulty.0, baseline.0, "rand:{seed} diverged");
        assert_eq!(faulty.1, baseline.1);
    }
}

#[test]
fn quarantine_opens_after_consecutive_deterministic_failures() {
    // Solo baseline for the surviving tenant.
    let mut sess = Session::compile(FIB, cfg(), DeviceSpec::h100()).unwrap();
    let solo = sess.run("fib", &[Value::from_i64(12)]).unwrap();

    let resil = ResilienceConfig {
        retry: true,
        quarantine_after: 2,
        max_retries: 16,
        backoff_base: 1 << 8,
        ..Default::default()
    };
    let mut eng = engine(cfg(), AdmissionPolicy::FairShare);
    eng.set_resilience(resil);
    let keep = eng.open_session("keep", FIB).unwrap();
    let poison = eng.open_session("poison", FIB).unwrap();
    for _ in 0..3 {
        eng.submit(keep, "fib", &[Value::from_i64(12)], SubmitOpts::default())
            .unwrap();
        // deadline below dev.startup: evicts before the first task runs,
        // with the fault plan inert — a deterministic zero-progress
        // failure, the circuit breaker's trigger
        eng.submit(
            poison,
            "fib",
            &[Value::from_i64(20)],
            SubmitOpts {
                deadline: Some(1),
                ..Default::default()
            },
        )
        .unwrap();
    }
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 6);

    // Round 1 admits poison job #1: deterministic failure #1, retried
    // with backoff. Round 2 admits poison job #2 (job #1 is still backing
    // off): deterministic failure #2 opens the breaker. The remaining
    // pending poison jobs — including the backed-off retry — are swept as
    // Quarantined without ever reaching the device again.
    let tr = eng.tenant_resilience(poison);
    assert!(tr.quarantined);
    assert!(tr.quarantined_at.is_some());
    assert_eq!(tr.consecutive_failures, 2);
    let pf: Vec<_> = outs.iter().filter(|o| o.tenant == poison).collect();
    assert_eq!(pf.len(), 3);
    let tripped: Vec<_> = pf
        .iter()
        .filter(|o| o.status == JobStatus::Failed(JobError::DeadlineEvicted))
        .collect();
    assert_eq!(tripped.len(), 1, "exactly one job trips the breaker");
    assert_eq!(tripped[0].attempts, 1);
    let mut swept_attempts: Vec<u32> = pf
        .iter()
        .filter(|o| o.status == JobStatus::Failed(JobError::Quarantined))
        .map(|o| o.attempts)
        .collect();
    swept_attempts.sort_unstable();
    // one never admitted, one the backed-off retry of the first failure
    assert_eq!(swept_attempts, vec![0, 1]);
    assert_eq!(eng.accounting(poison).jobs_retried, 1);
    assert_eq!(eng.accounting(poison).jobs_failed, 3);

    // new submissions for the quarantined tenant are refused, typed
    let err = eng
        .submit(poison, "fib", &[Value::from_i64(5)], SubmitOpts::default())
        .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Quarantined);

    // the co-tenant never noticed: every job pinned to the solo baseline
    let kf: Vec<_> = outs.iter().filter(|o| o.tenant == keep).collect();
    assert_eq!(kf.len(), 3);
    for o in &kf {
        assert_eq!(o.status, JobStatus::Completed);
        assert_eq!(o.result, solo.root_result);
        assert_eq!(o.stats.tasks_finished, solo.tasks_finished);
        assert_eq!(o.stats.spawns, solo.spawns);
        assert_eq!(o.stats.segments, solo.segments);
    }
    assert_eq!(eng.accounting(keep).jobs_completed, 3);
}

/// FNV-1a over the debug rendering — the same digest scheme the service
/// bench uses for its replay pin.
fn digest(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// One backpressure/shedding schedule, parameterized by seed (the seed
/// varies submission priorities), digesting the full outcome stream plus
/// the engine's backpressure counter.
fn shed_schedule_digest(seed: u64) -> u64 {
    let mut eng = engine(cfg(), AdmissionPolicy::PriorityWeighted);
    eng.set_resilience(ResilienceConfig {
        shed_watermark: Some(2),
        ..Default::default()
    });
    let t = eng.open_session("t", FIB).unwrap();
    let mut shed = 0u64;
    let mut backpressured = 0u64;
    for i in 0..6u64 {
        // deterministic per-seed priority pattern
        let pri = ((seed.wrapping_mul(0x9E37_79B9).wrapping_add(i * 7)) % 4) as u8;
        let before = eng.pending_jobs();
        match eng
            .try_submit(
                t,
                "fib",
                &[Value::from_i64(8)],
                SubmitOpts {
                    priority: pri,
                    ..Default::default()
                },
            )
            .unwrap()
        {
            SubmitResult::Admitted(_) => {
                if eng.pending_jobs() == before {
                    shed += 1; // admitted by displacing a pending job
                }
            }
            SubmitResult::Backpressure { pending, watermark } => {
                assert_eq!(watermark, 2);
                assert!(pending >= watermark);
                backpressured += 1;
            }
        }
        if i == 3 {
            // drain mid-schedule so later submissions see a short queue
            assert!(eng.run_round().unwrap());
        }
    }
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(eng.backpressure_events(), backpressured);
    let shed_outs = outs
        .iter()
        .filter(|o| o.status == JobStatus::Failed(JobError::Shed))
        .count() as u64;
    assert_eq!(shed_outs, shed);
    digest(&format!("{outs:?}|{backpressured}"))
}

#[test]
fn backpressure_and_shedding_are_deterministic_across_thread_counts() {
    // The CI job runs this test under GTAP_BENCH_THREADS=1 and =4; inside
    // one process, parallel_map's output must equal the serial map.
    let seeds: Vec<u64> = (0..6).collect();
    let serial: Vec<u64> = seeds.iter().map(|&s| shed_schedule_digest(s)).collect();
    let parallel = sweep::parallel_map(seeds, shed_schedule_digest);
    assert_eq!(serial, parallel);
    // the seeds vary priorities, so the schedules must actually differ —
    // otherwise the determinism check above is vacuous
    assert!(serial.windows(2).any(|w| w[0] != w[1]));
}

#[test]
fn checkpointed_retries_reexecute_strictly_less_than_root_retries() {
    // Size the per-job deadline slice from the measured solo makespan:
    // big enough to make real progress every attempt, too small to finish
    // in one. The slice is NOT escalated across retries — resuming from
    // the checkpoint is the progress mechanism.
    let mut sess = Session::compile(FIB, cfg(), DeviceSpec::h100()).unwrap();
    let solo = sess.run("fib", &[Value::from_i64(13)]).unwrap();
    let startup = DeviceSpec::h100().startup;
    assert!(solo.cycles > startup);
    let slice = startup + (solo.cycles - startup) * 2 / 3;

    let run = |checkpoint: bool| -> (JobOutcome, u64) {
        let mut eng = engine(cfg(), AdmissionPolicy::Fifo);
        eng.set_resilience(ResilienceConfig {
            retry: true,
            max_retries: 10,
            retry_budget: 16,
            backoff_base: 1 << 8,
            checkpoint,
            ..Default::default()
        });
        let t = eng.open_session("t", FIB).unwrap();
        eng.submit(
            t,
            "fib",
            &[Value::from_i64(13)],
            SubmitOpts {
                deadline: Some(slice),
                ..Default::default()
            },
        )
        .unwrap();
        eng.run_to_idle().unwrap();
        let outs = eng.take_outcomes();
        assert_eq!(outs.len(), 1);
        (outs[0].clone(), eng.accounting(t).tasks_reexecuted)
    };

    let (with_ck, reexec_ck) = run(true);
    assert_eq!(with_ck.status, JobStatus::Completed);
    assert!(with_ck.attempts > 1, "the slice must force at least one retry");
    assert_eq!(with_ck.result.unwrap().as_i64(), fib::reference(13));
    assert_eq!(
        reexec_ck, 0,
        "restored frontiers never re-run a finished segment"
    );

    // Without checkpointing the identical slice restarts from the root
    // every attempt: no attempt can get further than the first, so the
    // job exhausts its retries and every attempt's work is re-executed.
    let (without_ck, reexec_root) = run(false);
    assert_eq!(
        without_ck.status,
        JobStatus::Failed(JobError::DeadlineEvicted)
    );
    assert_eq!(without_ck.attempts, 11, "max_retries + 1 attempts");
    assert!(
        reexec_root > 0,
        "root retries throw away each attempt's finished tasks"
    );
    assert!(reexec_ck < reexec_root, "checkpointing strictly reduces re-execution");
}

#[test]
fn resilience_off_is_byte_identical_to_the_plain_engine() {
    // A schedule touching completion, deadline eviction, and cancellation.
    let run = |arm: Option<ResilienceConfig>| -> Vec<JobOutcome> {
        let mut eng = engine(cfg(), AdmissionPolicy::FairShare);
        if let Some(r) = arm {
            eng.set_resilience(r);
        }
        let a = eng.open_session("a", FIB).unwrap();
        let b = eng.open_session("b", FIB).unwrap();
        let token = CancelToken::new();
        eng.submit(a, "fib", &[Value::from_i64(11)], SubmitOpts::default())
            .unwrap();
        eng.submit(
            b,
            "fib",
            &[Value::from_i64(20)],
            SubmitOpts {
                deadline: Some(1),
                ..Default::default()
            },
        )
        .unwrap();
        eng.submit(
            a,
            "fib",
            &[Value::from_i64(9)],
            SubmitOpts {
                cancel: Some(token.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        token.cancel();
        eng.run_to_idle().unwrap();
        eng.take_outcomes()
    };
    let plain = run(None);
    // every knob moved EXCEPT the master switches — must all be inert
    let armed_off = run(Some(ResilienceConfig {
        retry: false,
        shed_watermark: None,
        max_retries: 3,
        retry_budget: 1,
        backoff_base: 7,
        quarantine_after: 1,
        checkpoint: false,
    }));
    assert_eq!(plain, armed_off, "retry off must stay byte-identical");
}

#[test]
fn evictions_carry_typed_errors_with_retry_off() {
    // Per-tenant deadline → DeadlineEvicted, typed on both the outcome
    // and the scheduler's TenantStats (PR-6 surfaced these only as a
    // boolean `evicted`).
    let mut eng = engine(cfg(), AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    eng.submit(
        t,
        "fib",
        &[Value::from_i64(20)],
        SubmitOpts {
            deadline: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs[0].status, JobStatus::Evicted);
    assert_eq!(outs[0].error, Some(JobError::DeadlineEvicted));
    assert_eq!(outs[0].stats.evict_cause, Some(EvictCause::Deadline));
    assert_eq!(outs[0].attempts, 1);

    // Fault-plane deadline (whole-run drain) → RunDrained. The slice is
    // 2k cycles past startup: far too thin for fib(16) to finish.
    let mut eng = engine(cfg_with_faults("deadline@52000"), AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    eng.submit(t, "fib", &[Value::from_i64(16)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs[0].status, JobStatus::Evicted);
    assert_eq!(outs[0].error, Some(JobError::RunDrained));
    assert_eq!(outs[0].stats.evict_cause, Some(EvictCause::Drain));

    // completed jobs carry no error
    let mut eng = engine(cfg(), AdmissionPolicy::Fifo);
    let t = eng.open_session("t", FIB).unwrap();
    eng.submit(t, "fib", &[Value::from_i64(8)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs[0].status, JobStatus::Completed);
    assert_eq!(outs[0].error, None);
}

#[test]
fn retry_budget_exhaustion_fails_typed() {
    // A poison job (zero-progress deadline) against a tiny retry budget
    // and a breaker that never opens: the job fails typed once the
    // per-job budget is spent.
    let mut eng = engine(cfg(), AdmissionPolicy::Fifo);
    eng.set_resilience(ResilienceConfig {
        retry: true,
        max_retries: 2,
        quarantine_after: 100,
        backoff_base: 1 << 8,
        ..Default::default()
    });
    let t = eng.open_session("t", FIB).unwrap();
    eng.submit(
        t,
        "fib",
        &[Value::from_i64(20)],
        SubmitOpts {
            deadline: Some(1),
            ..Default::default()
        },
    )
    .unwrap();
    eng.run_to_idle().unwrap();
    let outs = eng.take_outcomes();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].status, JobStatus::Failed(JobError::DeadlineEvicted));
    assert_eq!(outs[0].attempts, 3, "initial + max_retries");
    assert_eq!(eng.accounting(t).jobs_retried, 2);
    assert_eq!(eng.accounting(t).jobs_failed, 1);
    assert!(!eng.tenant_resilience(t).quarantined);
}
