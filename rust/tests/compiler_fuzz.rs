//! Differential fuzzing of the gtapc pipeline: random integer expression
//! trees are compiled to bytecode and executed on the simulator; results
//! must match a direct AST evaluation done in the test. This exercises
//! codegen's register allocation, temp recycling, short-circuit lowering,
//! ternaries and division guards end to end.
//!
//! The same random programs also run one segment through all four
//! interpreter tiers (reference / decoded / superblock-fused /
//! trace-fused), asserting identical `SegmentOutput`s — the fuzz half of
//! the superblock/trace cost-transparency invariant
//! (`rust/tests/interp_differential.rs` holds the workload half). The
//! traced tier runs twice per case: once with static prediction and once
//! with an **inverted branch profile** (anti-biased branch streams), so
//! side-exit-heavy traces are fuzzed on arbitrary shapes too.

mod common;

use common::{
    bfs_setup, inverted_profile_for, msort_setup, run_mem_workload_tier,
    run_mem_workload_tier_profiled, Tier,
};
use gtap::bench::runners::Exec;
use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, NO_TASK};
use gtap::coordinator::Session;
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::ir::traced::TracedModule;
use gtap::ir::types::Value;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::{BranchProfile, DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use gtap::util::prop::{Gen, Runner};

/// A random expression over variables a, b, c with C semantics.
#[derive(Debug, Clone)]
enum E {
    Var(usize),
    Lit(i64),
    Add(Box<E>, Box<E>),
    Sub(Box<E>, Box<E>),
    Mul(Box<E>, Box<E>),
    Div(Box<E>, Box<E>),
    Rem(Box<E>, Box<E>),
    And(Box<E>, Box<E>),
    Or(Box<E>, Box<E>),
    Xor(Box<E>, Box<E>),
    Shl(Box<E>, Box<E>),
    Shr(Box<E>, Box<E>),
    Lt(Box<E>, Box<E>),
    Eq(Box<E>, Box<E>),
    LAnd(Box<E>, Box<E>),
    LOr(Box<E>, Box<E>),
    Not(Box<E>),
    Neg(Box<E>),
    BitNot(Box<E>),
    Tern(Box<E>, Box<E>, Box<E>),
}

fn gen_expr(g: &mut Gen, depth: usize) -> E {
    if depth == 0 || g.chance(0.3) {
        return if g.chance(0.5) {
            E::Var(g.usize(0, 2))
        } else {
            E::Lit(g.int(-64, 64))
        };
    }
    let d = depth - 1;
    match g.int(0, 18) {
        0 => E::Add(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        1 => E::Sub(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        2 => E::Mul(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        3 => E::Div(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        4 => E::Rem(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        5 => E::And(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        6 => E::Or(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        7 => E::Xor(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        8 => E::Shl(Box::new(gen_expr(g, d)), Box::new(E::Lit(g.int(0, 8)))),
        9 => E::Shr(Box::new(gen_expr(g, d)), Box::new(E::Lit(g.int(0, 8)))),
        10 => E::Lt(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        11 => E::Eq(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        12 => E::LAnd(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        13 => E::LOr(Box::new(gen_expr(g, d)), Box::new(gen_expr(g, d))),
        14 => E::Not(Box::new(gen_expr(g, d))),
        15 => E::Neg(Box::new(gen_expr(g, d))),
        16 => E::BitNot(Box::new(gen_expr(g, d))),
        _ => E::Tern(
            Box::new(gen_expr(g, d)),
            Box::new(gen_expr(g, d)),
            Box::new(gen_expr(g, d)),
        ),
    }
}

fn render(e: &E) -> String {
    match e {
        E::Var(i) => ["a", "b", "c"][*i].to_string(),
        E::Lit(v) => {
            if *v < 0 {
                format!("({v})")
            } else {
                format!("{v}")
            }
        }
        E::Add(l, r) => format!("({} + {})", render(l), render(r)),
        E::Sub(l, r) => format!("({} - {})", render(l), render(r)),
        E::Mul(l, r) => format!("({} * {})", render(l), render(r)),
        E::Div(l, r) => format!("({} / {})", render(l), render(r)),
        E::Rem(l, r) => format!("({} % {})", render(l), render(r)),
        E::And(l, r) => format!("({} & {})", render(l), render(r)),
        E::Or(l, r) => format!("({} | {})", render(l), render(r)),
        E::Xor(l, r) => format!("({} ^ {})", render(l), render(r)),
        E::Shl(l, r) => format!("({} << {})", render(l), render(r)),
        E::Shr(l, r) => format!("({} >> {})", render(l), render(r)),
        E::Lt(l, r) => format!("({} < {})", render(l), render(r)),
        E::Eq(l, r) => format!("({} == {})", render(l), render(r)),
        E::LAnd(l, r) => format!("({} && {})", render(l), render(r)),
        E::LOr(l, r) => format!("({} || {})", render(l), render(r)),
        E::Not(x) => format!("(!{})", render(x)),
        E::Neg(x) => format!("(-{})", render(x)),
        E::BitNot(x) => format!("(~{})", render(x)),
        E::Tern(c, t, f) => format!("({} ? {} : {})", render(c), render(t), render(f)),
    }
}

/// C/DSL semantics (wrapping; div/rem by zero -> 0 as in the interpreter).
fn eval(e: &E, v: &[i64; 3]) -> i64 {
    let b = |x: bool| x as i64;
    match e {
        E::Var(i) => v[*i],
        E::Lit(x) => *x,
        E::Add(l, r) => eval(l, v).wrapping_add(eval(r, v)),
        E::Sub(l, r) => eval(l, v).wrapping_sub(eval(r, v)),
        E::Mul(l, r) => eval(l, v).wrapping_mul(eval(r, v)),
        E::Div(l, r) => {
            let d = eval(r, v);
            if d == 0 {
                0
            } else {
                eval(l, v).wrapping_div(d)
            }
        }
        E::Rem(l, r) => {
            let d = eval(r, v);
            if d == 0 {
                0
            } else {
                eval(l, v).wrapping_rem(d)
            }
        }
        E::And(l, r) => eval(l, v) & eval(r, v),
        E::Or(l, r) => eval(l, v) | eval(r, v),
        E::Xor(l, r) => eval(l, v) ^ eval(r, v),
        E::Shl(l, r) => eval(l, v).wrapping_shl(eval(r, v) as u32),
        E::Shr(l, r) => eval(l, v).wrapping_shr(eval(r, v) as u32),
        E::Lt(l, r) => b(eval(l, v) < eval(r, v)),
        E::Eq(l, r) => b(eval(l, v) == eval(r, v)),
        E::LAnd(l, r) => b(eval(l, v) != 0 && eval(r, v) != 0),
        E::LOr(l, r) => b(eval(l, v) != 0 || eval(r, v) != 0),
        E::Not(x) => b(eval(x, v) == 0),
        E::Neg(x) => eval(x, v).wrapping_neg(),
        E::BitNot(x) => !eval(x, v),
        E::Tern(c, t, f) => {
            if eval(c, v) != 0 {
                eval(t, v)
            } else {
                eval(f, v)
            }
        }
    }
}

#[test]
fn fuzz_expressions_match_reference() {
    Runner::new().cases(150).run("expr-fuzz", |g| {
        let e = gen_expr(g, 5);
        let src = format!(
            "#pragma gtap function\nint f(int a, int b, int c) {{ return {}; }}",
            render(&e)
        );
        let exec = Exec::gpu_thread(1, 32);
        let mut session =
            Session::compile(&src, exec.cfg.clone(), exec.device.clone()).unwrap_or_else(|err| {
                panic!("compile failed for {src}\n{err}")
            });
        let args = [g.int(-100, 100), g.int(-100, 100), g.int(-100, 100)];
        let stats = session
            .run(
                "f",
                &[
                    Value::from_i64(args[0]),
                    Value::from_i64(args[1]),
                    Value::from_i64(args[2]),
                ],
            )
            .unwrap();
        let got = stats.root_result.unwrap().as_i64();
        let want = eval(&e, &args);
        assert_eq!(got, want, "args {args:?}, src:\n{src}");
    });
}

/// One segment of `src`'s function 0 through a tier on fresh state;
/// returns (end-kind marker, cycles, path, result word, spawn count).
/// Tiers: 0 = reference, 1 = decoded, 2 = fused, 3 = traced (static
/// prediction), 4 = traced built from an inverted branch profile (every
/// biased branch mispredicted — side-exit-heavy traces).
fn run_segment_tier(
    src: &str,
    args: &[i64],
    tier: u8,
) -> (bool, u64, u64, u64, usize) {
    let module = compile_default(src).unwrap();
    let decoded = DecodedModule::decode(&module);
    let dev = DeviceSpec::h100();
    let fm = FusedModule::fuse(&decoded, &dev);
    let words = module.funcs[0].layout.words().max(1);
    let mut records = RecordPool::new(8, words, 2);
    let mut mem = Memory::new(module.globals_words());
    let task = records.alloc(0, NO_TASK).unwrap();
    for (i, &a) in args.iter().enumerate() {
        records.data_mut(task)[i] = a as u64;
    }
    let mut log = Vec::new();
    let (out, spawns) = if tier == 0 {
        let interp = RefInterp {
            module: &module,
            dev: &dev,
            block_width: 1,
            xla_payload: false,
            record_accesses: false,
        };
        let mut frame = RefLaneFrame::new();
        frame.reset(&module, task, 0, 0, 0);
        match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
            StepResult::Done(o) => (o, frame.spawns().len()),
            other => panic!("unexpected {other:?}"),
        }
    } else {
        let tm;
        let interp = match tier {
            2 => Interp::fused(&decoded, &fm, &dev, 1, false),
            3 | 4 => {
                let profile = (tier == 4).then(|| {
                    // record the real branch stream on throwaway state,
                    // then invert it: every trace predicts against the
                    // hot path and must recover through side exits
                    let mut records2 = RecordPool::new(8, words, 2);
                    let mut mem2 = Memory::new(module.globals_words());
                    let task2 = records2.alloc(0, NO_TASK).unwrap();
                    for (i, &a) in args.iter().enumerate() {
                        records2.data_mut(task2)[i] = a as u64;
                    }
                    let mut p = BranchProfile::new(decoded.insns.len());
                    let mut f2 = LaneFrame::sized(&decoded);
                    f2.reset(&decoded, task2, 0, 0, 0);
                    let mut log2 = Vec::new();
                    let dec = Interp::new(&decoded, &dev, 1, false);
                    match dec.run_profiled(&mut f2, &mut mem2, &mut records2, &mut log2, &mut p)
                    {
                        StepResult::Done(_) => {}
                        other => panic!("unexpected {other:?}"),
                    }
                    p.inverted()
                });
                tm = TracedModule::build(&decoded, &fm, &dev, profile.as_ref());
                Interp::traced(&decoded, &tm, &dev, 1, false)
            }
            _ => Interp::new(&decoded, &dev, 1, false),
        };
        let mut frame = LaneFrame::sized(&decoded);
        frame.reset(&decoded, task, 0, 0, 0);
        match interp.run(&mut frame, &mut mem, &mut records, &mut log) {
            StepResult::Done(o) => (o, frame.spawns().len()),
            other => panic!("unexpected {other:?}"),
        }
    };
    let finished = matches!(out.end, gtap::sim::SegmentEnd::Finish);
    let result = module.funcs[0]
        .layout
        .result_offset()
        .map(|off| records.data(task)[off as usize])
        .unwrap_or(0);
    (finished, out.cycles, out.path, result, spawns)
}

#[test]
fn fuzz_segments_agree_across_ref_decoded_fused_traced() {
    // Random expression programs (ternaries give real branch structure, so
    // superblock partitions, CmpBr/ConstBin macro-ops, trace formation and
    // scratch demotion get exercised on arbitrary shapes, not just the
    // workloads).
    Runner::new().cases(80).run("interp-tier-fuzz", |g| {
        let e = gen_expr(g, 5);
        let src = format!(
            "#pragma gtap function\nint f(int a, int b, int c) {{ return {}; }}",
            render(&e)
        );
        let args = [g.int(-100, 100), g.int(-100, 100), g.int(-100, 100)];
        let reference = run_segment_tier(&src, &args, 0);
        let decoded = run_segment_tier(&src, &args, 1);
        let fused = run_segment_tier(&src, &args, 2);
        let traced = run_segment_tier(&src, &args, 3);
        let traced_anti = run_segment_tier(&src, &args, 4);
        // end/cycles/result/spawns: identical across all tiers, including
        // the side-exit-heavy anti-profiled traced build
        assert_eq!(
            (reference.0, reference.1, reference.3, reference.4),
            (decoded.0, decoded.1, decoded.3, decoded.4),
            "decoded vs ref, args {args:?}, src:\n{src}"
        );
        for (name, o) in [
            ("fused", &fused),
            ("traced", &traced),
            ("traced-anti", &traced_anti),
        ] {
            assert_eq!(
                (decoded.0, decoded.1, decoded.3, decoded.4),
                (o.0, o.1, o.3, o.4),
                "{name} vs decoded, args {args:?}, src:\n{src}"
            );
            // path hashes: bit-identical to decoded (global-pc folds)
            assert_eq!(
                decoded.2, o.2,
                "{name} path hash diverged, args {args:?}, src:\n{src}"
            );
        }
        // and the result still matches the direct AST evaluation
        assert_eq!(fused.3 as i64, eval(&e, &args), "src:\n{src}");
    });
}

#[test]
fn fuzz_bfs_segments_agree_across_tiers() {
    // random CSR graphs and start vertices: the pointer-chasing +
    // parallel_for + atomic_min segment family through all four tiers
    // (shared harness: tests/common/mod.rs)
    let src = gtap::workloads::bfs::source();
    Runner::new().cases(30).run("bfs-tier-fuzz", |g| {
        let n = g.usize(2, 24);
        let seed = g.int(0, 1 << 20) as u64;
        let graph = gtap::workloads::bfs::CsrGraph::random(n, g.usize(1, 4), seed);
        let v = g.usize(0, n - 1) as i64;
        let setup = bfs_setup(&graph, v);
        let reference = run_mem_workload_tier(&src, 0, Tier::Ref, false, 64, &setup);
        let decoded = run_mem_workload_tier(&src, 0, Tier::Decoded, false, 64, &setup);
        let fused = run_mem_workload_tier(&src, 0, Tier::Fused, false, 64, &setup);
        let traced = run_mem_workload_tier(&src, 0, Tier::Traced, false, 64, &setup);
        // cycles/spawns/streams/memory: identical across all four; paths
        // bit-identical to decoded for the fused and traced tiers (the
        // reference folds function-local pcs)
        assert_eq!(
            reference.functional(),
            decoded.functional(),
            "decoded vs ref bfs (n {n}, v {v})"
        );
        assert_eq!(decoded, fused, "fused vs decoded bfs (n {n}, v {v})");
        assert_eq!(decoded, traced, "traced vs decoded bfs (n {n}, v {v})");
    });
}

#[test]
fn fuzz_sort_segments_agree_across_tiers() {
    // random arrays, bounds and cutoffs through mergesort's leaf, split
    // and merge-continuation segments (shared harness: tests/common)
    Runner::new().cases(30).run("sort-tier-fuzz", |g| {
        let cutoff = g.int(2, 16);
        let src = gtap::workloads::sort::mergesort_source(cutoff);
        let n = g.usize(2, 48);
        let xs = gtap::workloads::sort::input(n, g.int(0, 1 << 20) as u64);
        let left = g.usize(0, n - 1) as i64;
        let right = g.usize(left as usize + 1, n) as i64;
        let state = if g.chance(0.3) && right - left > cutoff {
            1u16
        } else {
            0
        };
        let setup = msort_setup(&xs, state, left, right);
        let reference = run_mem_workload_tier(&src, state, Tier::Ref, false, 1, &setup);
        let decoded = run_mem_workload_tier(&src, state, Tier::Decoded, false, 1, &setup);
        let fused = run_mem_workload_tier(&src, state, Tier::Fused, false, 1, &setup);
        let traced = run_mem_workload_tier(&src, state, Tier::Traced, false, 1, &setup);
        // anti-profiled traced build: every biased branch predicts against
        // the segment's real stream, so traces side-exit almost every
        // dispatch — the spill-at-exit path must stay cost-transparent
        let anti = inverted_profile_for(&src, state, 1, &setup);
        let traced_anti =
            run_mem_workload_tier_profiled(&src, state, Tier::Traced, false, 1, Some(&anti), &setup);
        assert_eq!(
            reference.functional(),
            decoded.functional(),
            "decoded vs ref msort (n {n}, {left}..{right}, state {state})"
        );
        assert_eq!(
            decoded, fused,
            "fused vs decoded msort (n {n}, {left}..{right}, state {state})"
        );
        assert_eq!(
            decoded, traced,
            "traced vs decoded msort (n {n}, {left}..{right}, state {state})"
        );
        assert_eq!(
            decoded, traced_anti,
            "anti-profiled traced vs decoded msort (n {n}, {left}..{right}, state {state})"
        );
        if state == 0 && right - left > cutoff {
            assert_eq!(decoded.spawns, 2, "split segments spawn both halves");
        }
    });
}

#[test]
fn fuzz_expressions_in_loops() {
    // the same expressions inside a summing loop exercise register reuse
    // across iterations and branch back-edges
    Runner::new().cases(40).run("loop-expr-fuzz", |g| {
        let e = gen_expr(g, 3);
        let src = format!(
            "#pragma gtap function\nint f(int a, int b, int c) {{\n\
             int s = 0;\nint i = 0;\nwhile (i < 4) {{ s = s + ({}); a = a + 1; i = i + 1; }}\n\
             return s; }}",
            render(&e)
        );
        let exec = Exec::gpu_thread(1, 32);
        let mut session = Session::compile(&src, exec.cfg.clone(), exec.device.clone())
            .unwrap_or_else(|err| panic!("compile failed for {src}\n{err}"));
        let args = [g.int(-50, 50), g.int(-50, 50), g.int(-50, 50)];
        let stats = session
            .run(
                "f",
                &[
                    Value::from_i64(args[0]),
                    Value::from_i64(args[1]),
                    Value::from_i64(args[2]),
                ],
            )
            .unwrap();
        let mut want = 0i64;
        let mut v = args;
        for _ in 0..4 {
            want = want.wrapping_add(eval(&e, &v));
            v[0] += 1;
        }
        assert_eq!(stats.root_result.unwrap().as_i64(), want, "src:\n{src}");
    });
}
