//! Edge cases and invariants across the stack: degenerate problem sizes,
//! stats consistency, and divergence-model properties.

use gtap::bench::runners::{self, Exec};
use gtap::coordinator::{
    Backoff, GtapConfig, Placement, QueueSelect, SchedulerKind, Session, SmTier, StealAmount,
    VictimSelect,
};
use gtap::ir::types::Value;
use gtap::sim::divergence::{warp_cycles, LanePath};
use gtap::sim::{DeviceSpec, Memory};
use gtap::util::prop::Runner;

#[test]
fn degenerate_problem_sizes() {
    let e = Exec::gpu_thread(2, 32);
    // fib base cases: single task, no spawns
    for n in [0, 1] {
        let out = runners::run_fib(&e, n, 0, false).unwrap();
        assert_eq!(out.stats.tasks_finished, 1);
        assert_eq!(out.stats.spawns, 0);
    }
    // 1-element and 2-element sorts
    runners::run_mergesort(&e, 1, 16, 1).unwrap();
    runners::run_mergesort(&e, 2, 16, 1).unwrap();
    runners::run_cilksort(&e, 2, 4, 8, false, 1).unwrap();
    // depth-0 tree: root only
    let out = runners::run_full_tree(&e, 0, 1, 1, None).unwrap();
    assert_eq!(out.stats.tasks_finished, 1);
    // 2-vertex graph
    runners::run_bfs(&Exec::gpu_block(2, 32).no_taskwait(), 2, 1, 1).unwrap();
    // nqueens trivial boards
    runners::run_nqueens(&e.clone().no_taskwait(), 1, 1, false).unwrap();
    runners::run_nqueens(&e.clone().no_taskwait(), 4, 2, false).unwrap();
}

#[test]
fn stats_are_consistent() {
    let out = runners::run_fib(&Exec::gpu_thread(4, 32), 15, 0, false).unwrap();
    let s = &out.stats;
    assert_eq!(s.tasks_finished, s.spawns + 1, "every task spawned once + root");
    assert!(s.segments >= s.tasks_finished, "every task runs ≥1 segment");
    assert!(s.iterations >= s.idle_iterations);
    assert!(s.steals_ok <= s.steal_attempts);
    assert!(s.cycles > 0 && s.seconds > 0.0);
    assert!(s.peak_live_records >= 1);
}

#[test]
fn profiler_accounting_consistent() {
    let out = runners::run_fib(&Exec::gpu_thread(4, 32).profiled(), 14, 0, false).unwrap();
    assert!(!out.profiler.events.is_empty());
    for e in &out.profiler.events {
        assert!(e.active_lanes as usize <= 32);
        assert!(e.path_groups <= e.active_lanes);
        if e.active_lanes == 0 {
            assert_eq!(e.busy, 0, "idle iterations must not report busy time");
        }
    }
    // busy totals are bounded by the run's makespan per worker
    for (_, busy, total) in out.profiler.utilization() {
        assert!(busy <= total);
        assert!(total <= out.stats.cycles);
    }
}

#[test]
fn prop_epaq_separation_never_increases_warp_time() {
    // the divergence model's defining property: separating two path
    // classes into two warps never costs more total warp time than two
    // mixed warps (this is what EPAQ exploits)
    Runner::new().cases(300).run("epaq-separation", |g| {
        let n = g.usize(1, 16);
        let short: Vec<LanePath> = (0..n)
            .map(|_| LanePath {
                hash: 1,
                cycles: g.int(1, 100) as u64,
            })
            .collect();
        let long: Vec<LanePath> = (0..n)
            .map(|_| LanePath {
                hash: 2,
                cycles: g.int(100, 10_000) as u64,
            })
            .collect();
        // mixed: interleave half/half into two warps
        let mut warp_a = vec![];
        let mut warp_b = vec![];
        for i in 0..n {
            if i % 2 == 0 {
                warp_a.push(short[i]);
                warp_b.push(long[i]);
            } else {
                warp_a.push(long[i]);
                warp_b.push(short[i]);
            }
        }
        let mixed = warp_cycles(&warp_a) + warp_cycles(&warp_b);
        let separated = warp_cycles(&short) + warp_cycles(&long);
        assert!(
            separated <= mixed,
            "separated {separated} > mixed {mixed} (n={n})"
        );
    });
}

#[test]
fn prop_warp_cycles_bounds() {
    // sum-of-max-per-group is between max(lane) and sum(lanes)
    Runner::new().cases(300).run("warp-cycle-bounds", |g| {
        let n = g.usize(1, 32);
        let lanes: Vec<LanePath> = (0..n)
            .map(|_| LanePath {
                hash: g.int(0, 4) as u64,
                cycles: g.int(0, 1000) as u64,
            })
            .collect();
        let w = warp_cycles(&lanes);
        let max = lanes.iter().map(|l| l.cycles).max().unwrap();
        let sum: u64 = lanes.iter().map(|l| l.cycles).sum();
        assert!(w >= max, "{w} < max {max}");
        assert!(w <= sum, "{w} > sum {sum}");
    });
}

#[test]
fn session_reuse_across_runs() {
    // memory persists, task state resets: two runs in one session
    let src = "global int g;\n#pragma gtap function\nvoid bump(int k) { g = g + k; }";
    let mut s = Session::compile(
        src,
        GtapConfig {
            grid_size: 2,
            block_size: 32,
            ..Default::default()
        },
        DeviceSpec::h100(),
    )
    .unwrap();
    s.run("bump", &[Value::from_i64(5)]).unwrap();
    s.run("bump", &[Value::from_i64(7)]).unwrap();
    assert_eq!(s.get_global("g").unwrap().as_i64(), 12);
}

#[test]
fn deep_recursion_mergesort_no_stack_issues() {
    // 2^15 elements with cutoff 4: ~8k tasks, depth ~13; the interpreter
    // must not recurse on the host stack
    runners::run_mergesort(&Exec::gpu_thread(16, 32), 1 << 15, 4, 9).unwrap();
}

#[test]
fn epaq_queue_index_clamped() {
    // queue(expr) values beyond GTAP_NUM_QUEUES-1 are clamped, not UB
    let src = r#"
        #pragma gtap function
        int f(int n) {
            if (n < 1) return 0;
            int a;
            #pragma gtap task queue(99)
            a = f(n - 1);
            #pragma gtap taskwait queue(1234567)
            return a + 1;
        }
    "#;
    let mut s = Session::compile(
        src,
        GtapConfig {
            grid_size: 2,
            block_size: 32,
            num_queues: 2,
            ..Default::default()
        },
        DeviceSpec::h100(),
    )
    .unwrap();
    let stats = s.run("f", &[Value::from_i64(10)]).unwrap();
    assert_eq!(stats.root_result.unwrap().as_i64(), 10);
}

#[test]
fn ablation_knobs_preserve_semantics() {
    // all scheduler variants must still compute correct results
    let base = Exec::gpu_thread(4, 32);
    let tweaks: Vec<Box<dyn Fn(Exec) -> Exec>> = vec![
        Box::new(|mut e: Exec| {
            e.cfg.immediate_buffer = false;
            e
        }),
        Box::new(|e: Exec| e.steal_amount(StealAmount::Fixed { max: Some(1) })),
        Box::new(|e: Exec| e.steal_amount(StealAmount::Half)),
        Box::new(|e: Exec| e.victim(VictimSelect::LocalityFirst)),
        Box::new(|e: Exec| e.victim(VictimSelect::OccupancyGuided)),
        Box::new(|mut e: Exec| {
            e.cfg.policy.queue_select = QueueSelect::Sticky;
            e
        }),
        Box::new(|mut e: Exec| {
            e.cfg.policy.queue_select = QueueSelect::LongestFirst;
            e
        }),
        Box::new(|mut e: Exec| {
            e.cfg.policy.placement = Placement::OwnQueue;
            e
        }),
        Box::new(|mut e: Exec| {
            e.cfg.policy.placement = Placement::RoundRobinSpill;
            e
        }),
        Box::new(|mut e: Exec| {
            e.cfg.policy.backoff = Backoff::FixedPoll;
            e
        }),
        Box::new(|e: Exec| e.steal_amount(StealAmount::Adaptive)),
        Box::new(|e: Exec| e.sm_tier(SmTier::Spill)),
        Box::new(|e: Exec| e.sm_tier(SmTier::Share)),
        Box::new(|e: Exec| {
            e.queue_select(QueueSelect::Priority)
                .placement(Placement::PriorityDepth)
        }),
        Box::new(|e: Exec| {
            e.queue_select(QueueSelect::Priority)
                .placement(Placement::PriorityUser)
        }),
    ];
    for t in tweaks {
        let e = t(base.clone());
        runners::run_fib(&e, 14, 0, false).unwrap();
        runners::run_full_tree(&e, 6, 4, 8, None).unwrap();
        runners::run_mergesort(&e, 500, 32, 3).unwrap();
    }
}

#[test]
fn priority_placement_single_worker_is_fifo_by_depth() {
    // With one worker, depth banding + priority acquisition and no
    // immediate-execution buffer, the scheduler degrades to breadth-first
    // FIFO-by-depth: every depth-d task executes before any depth-(d+1)
    // task. Observable through the captured print order.
    let src = r#"
        #pragma gtap function
        void walk(int d, int depth) {
            print_int(depth);
            if (d > 0) {
                #pragma gtap task
                walk(d - 1, depth + 1);
                #pragma gtap task
                walk(d - 1, depth + 1);
            }
        }
    "#;
    let mut cfg = GtapConfig {
        grid_size: 1,
        block_size: 32,
        num_queues: 8,
        assume_no_taskwait: true,
        immediate_buffer: false,
        ..Default::default()
    };
    cfg.policy.queue_select = QueueSelect::Priority;
    cfg.policy.placement = Placement::PriorityDepth;
    let mut s = Session::compile(src, cfg, DeviceSpec::h100()).unwrap();
    let stats = s
        .run("walk", &[Value::from_i64(4), Value::from_i64(0)])
        .unwrap();
    let depths: Vec<i64> = stats.output.iter().map(|l| l.parse().unwrap()).collect();
    assert_eq!(depths.len(), 31, "2^5 - 1 tasks, one print each");
    assert!(
        depths.windows(2).all(|w| w[0] <= w[1]),
        "execution order must be non-decreasing in depth: {depths:?}"
    );
    assert_eq!(*depths.last().unwrap(), 4);
}

#[test]
fn steal_policies_report_zero_steal_stats_without_victims() {
    // the steal path must not be entered (nor steal_attempts counted) when
    // the queue organization does not support stealing — whatever the
    // steal policies, including the adaptive controller, say
    for vs in VictimSelect::ALL {
        for sa in StealAmount::ALL {
            // sm_tier Share is requested but must be gated off by the
            // organization (QueueSet::supports_sm_tier → SmPool disabled),
            // so the zero sm_spills below tests the gate, not a default
            let e = Exec::gpu_thread(8, 32)
                .scheduler(SchedulerKind::GlobalQueue)
                .victim(vs)
                .steal_amount(sa)
                .sm_tier(SmTier::Share);
            let s = runners::run_fib(&e, 12, 0, false).unwrap().stats;
            assert_eq!(s.steal_attempts, 0, "{}/{}", vs.name(), sa.name());
            assert_eq!(s.steals_ok, 0, "{}/{}", vs.name(), sa.name());
            assert_eq!(s.sm_spills, 0, "no SM tier over a global queue");
            assert_eq!(s.sm_pool_hits, 0, "no SM tier over a global queue");
        }
    }
    // single worker: there is no victim, so no attempt may be counted
    for sa in StealAmount::ALL {
        let s = runners::run_fib(&Exec::gpu_thread(1, 32).steal_amount(sa), 12, 0, false)
            .unwrap()
            .stats;
        assert_eq!(s.steal_attempts, 0, "{}", sa.name());
        assert_eq!(s.steals_ok, 0, "{}", sa.name());
    }
}

#[test]
fn sm_tier_single_sm_without_overflow_is_a_noop() {
    // On a one-SM device (every worker shares the slice) the Spill tier
    // has nothing to do while no deque overflows: bit-identical RunStats
    // to the tier being off.
    let mut dev = DeviceSpec::h100();
    dev.sms = 1;
    let mut base = Exec::gpu_thread(4, 32);
    base.device = dev;
    let off = runners::run_fib(&base, 13, 0, false).unwrap().stats;
    let spill = runners::run_fib(&base.clone().sm_tier(SmTier::Spill), 13, 0, false)
        .unwrap()
        .stats;
    assert_eq!(off, spill, "spill tier must be a no-op absent overflow");
    assert_eq!(spill.sm_spills, 0);
    assert_eq!(spill.sm_pool_hits, 0);
}

#[test]
fn memory_alloc_geometric_growth_stays_functional() {
    // regression for the Memory::alloc hardening: interleaved small and
    // large allocations must keep exact base addresses and full data
    // integrity while the backing store grows geometrically
    let mut m = Memory::new(2);
    let mut expected_base = 2u64;
    let mut regions: Vec<(u64, Vec<i64>)> = vec![];
    for i in 0..200u64 {
        let n = 1 + (i % 37);
        let base = m.alloc(n);
        assert_eq!(base, expected_base, "bump allocation must stay exact");
        expected_base += n;
        let xs: Vec<i64> = (0..n as i64).map(|k| (i as i64) * 1000 + k).collect();
        m.write_i64s(base, &xs);
        regions.push((base, xs));
    }
    assert_eq!(m.size_words(), expected_base);
    for (base, xs) in &regions {
        assert_eq!(&m.read_i64s(*base, xs.len() as u64), xs, "region at {base}");
    }
}

#[test]
#[should_panic(expected = "overflows the address space")]
fn memory_alloc_brk_overflow_panics_instead_of_wrapping() {
    let mut m = Memory::new(0);
    m.alloc(8);
    m.alloc(u64::MAX); // would wrap brk without the checked add
}

#[test]
fn steal_one_slower_than_batched() {
    let batched = runners::run_fib(&Exec::gpu_thread(64, 32), 20, 0, false)
        .unwrap()
        .seconds;
    let e = Exec::gpu_thread(64, 32).steal_amount(StealAmount::Fixed { max: Some(1) });
    let one = runners::run_fib(&e, 20, 0, false).unwrap().seconds;
    assert!(one > batched, "steal-one {one} must be slower than batched {batched}");
}

#[test]
fn watchdog_never_trips_on_live_fault_free_runs() {
    // The watchdog is always armed, even with faults off; its quiescence
    // predicate must never fire on a healthy run. Each scenario below is
    // idle-heavy or long enough to cross many WATCHDOG_INTERVAL
    // boundaries, under both backoff pacers.
    for backoff in Backoff::ALL {
        let with_backoff = |mut e: Exec| -> Exec {
            e.cfg.policy.backoff = backoff;
            e
        };
        // idle-heavy: 16 workers fighting over a tiny task tree
        let out = runners::run_fib(&with_backoff(Exec::gpu_thread(16, 32)), 8, 0, false).unwrap();
        assert_eq!(out.stats.watchdog_trips, 0, "{backoff:?} idle-heavy");
        assert_eq!(out.stats.faults_injected, 0);
        // single worker: no steals, pure serial drain
        let out = runners::run_fib(&with_backoff(Exec::gpu_thread(1, 32)), 13, 0, false).unwrap();
        assert_eq!(out.stats.watchdog_trips, 0, "{backoff:?} single worker");
        // deep serial chain: one worker, long dependent mergesort spine
        let out =
            runners::run_mergesort(&with_backoff(Exec::gpu_thread(1, 32)), 400, 16, 5).unwrap();
        assert_eq!(out.stats.watchdog_trips, 0, "{backoff:?} serial chain");
        assert!(
            out.stats.cycles > gtap::coordinator::fault::watchdog::WATCHDOG_INTERVAL,
            "scenario too short to exercise the watchdog: {}",
            out.stats.cycles
        );
    }
}
