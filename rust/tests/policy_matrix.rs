//! Policy-matrix properties: every combination of the composable
//! scheduling policies is (i) semantically correct, (ii) deterministic
//! given a seed, and (iii) stable under the parallel bench harness —
//! `GTAP_BENCH_THREADS=1` and multi-threaded sweeps produce bit-identical
//! summaries, so policy experiments can fan out across host threads
//! without losing reproducibility.

use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::measure_curve;
use gtap::coordinator::{Backoff, Placement, PolicyConfig, RunStats, StealAmount, VictimSelect};
use std::sync::Mutex;

fn run_fib_with(p: PolicyConfig, seed: u64) -> RunStats {
    // EPAQ (3 queues) so queue selection and placement have real choices
    let e = Exec::gpu_thread(8, 32).queues(3).seed(seed).policy(p);
    runners::run_fib(&e, 13, 2, true).unwrap().stats
}

#[test]
fn every_steal_combo_is_correct_and_deterministic() {
    let combos = PolicyConfig::steal_matrix();
    assert_eq!(combos.len(), 27);
    for p in combos {
        let a = run_fib_with(p, 1);
        let b = run_fib_with(p, 1);
        assert_eq!(a, b, "non-deterministic under {}", p.label());
        // run_fib validated the result; sanity-check the flow stats too
        assert_eq!(a.tasks_finished, a.spawns + 1, "{}", p.label());
        assert!(a.steals_ok <= a.steal_attempts, "{}", p.label());
        // a different seed still computes the same (validated) result
        run_fib_with(p, 2);
    }
}

#[test]
fn placement_and_backoff_combos_are_correct_and_deterministic() {
    for pl in Placement::ALL {
        for bo in Backoff::ALL {
            let p = PolicyConfig {
                placement: pl,
                backoff: bo,
                ..Default::default()
            };
            let a = run_fib_with(p, 3);
            let b = run_fib_with(p, 3);
            assert_eq!(a, b, "non-deterministic under {}", p.label());
        }
    }
}

#[test]
fn distinct_policies_actually_schedule_differently() {
    // the axes must be observable, not cosmetic: steal-one claims less per
    // steal than batched, so it needs at least as many successful steals,
    // and strictly more pops+steals overall on a steal-heavy run
    let batched = run_fib_with(PolicyConfig::default(), 5);
    let one = run_fib_with(
        PolicyConfig {
            steal_amount: StealAmount::Fixed { max: Some(1) },
            ..Default::default()
        },
        5,
    );
    assert_eq!(batched.tasks_finished, one.tasks_finished);
    assert_ne!(
        (batched.cycles, batched.steals_ok, batched.pops),
        (one.cycles, one.steals_ok, one.pops),
        "steal-one must be observably different from batched stealing"
    );
}

#[test]
fn rr_spill_survives_tight_queue_capacity() {
    // rr-spill's contract: tight per-class budgets must not abort the run;
    // overflowing batches split across the classes by free space. The run
    // is validated (run_fib checks the closed form), so any misrouted or
    // dropped child shows up as a wrong result.
    let mut e = Exec::gpu_thread(2, 32).queues(3).queue_capacity(64);
    e.cfg.policy.placement = Placement::RoundRobinSpill;
    runners::run_fib(&e, 14, 2, true).unwrap();
}

#[test]
fn global_queue_runs_report_zero_steal_stats() {
    // regression: the steal path must not be entered (nor steal_attempts
    // counted) when the queue organization does not support stealing —
    // whatever the steal policies say
    for vs in VictimSelect::ALL {
        for sa in StealAmount::ALL {
            let e = Exec::gpu_thread(8, 32)
                .scheduler(gtap::coordinator::SchedulerKind::GlobalQueue)
                .victim(vs)
                .steal_amount(sa);
            let s = runners::run_fib(&e, 12, 0, false).unwrap().stats;
            assert_eq!(s.steal_attempts, 0, "{}/{}", vs.name(), sa.name());
            assert_eq!(s.steals_ok, 0, "{}/{}", vs.name(), sa.name());
        }
    }
}

#[test]
fn single_worker_runs_report_zero_steal_stats() {
    // one warp: there is no victim, so no attempt may be counted
    let s = runners::run_fib(&Exec::gpu_thread(1, 32), 12, 0, false)
        .unwrap()
        .stats;
    assert_eq!(s.steal_attempts, 0);
    assert_eq!(s.steals_ok, 0);
}

/// Serializes access to the GTAP_BENCH_* environment within this binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn with_env<R>(pairs: &[(&str, &str)], f: impl FnOnce() -> R) -> R {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for (k, v) in pairs {
        std::env::set_var(k, v);
    }
    let r = f();
    for (k, _) in pairs {
        std::env::remove_var(k);
    }
    r
}

#[test]
fn policy_sweep_identical_across_thread_counts() {
    // the full steal matrix as one sweep: serial vs 4 harness threads must
    // be byte-identical (the bench-layer determinism contract extends to
    // the policy axes)
    let combos = PolicyConfig::steal_matrix();
    let curve = |combos: &[PolicyConfig]| {
        measure_curve(combos, |p, seed| run_fib_with(*p, seed).cycles as f64)
    };
    let serial = with_env(
        &[("GTAP_BENCH_RUNS", "2"), ("GTAP_BENCH_THREADS", "1")],
        || curve(&combos),
    );
    let parallel = with_env(
        &[("GTAP_BENCH_RUNS", "2"), ("GTAP_BENCH_THREADS", "4")],
        || curve(&combos),
    );
    assert_eq!(serial.len(), parallel.len());
    for ((pa, sa), (pb, sb)) in serial.iter().zip(parallel.iter()) {
        assert_eq!(pa, pb);
        assert_eq!(
            sa.median.to_bits(),
            sb.median.to_bits(),
            "thread count changed the sweep result for {}",
            pa.label()
        );
        assert_eq!(sa.mean.to_bits(), sb.mean.to_bits());
    }
}
