//! Model-based property tests for the batched task-queue operations:
//! [`TaskQueue`] (warp-cooperative PopBatch/StealBatch/PushBatch,
//! Algorithm 1) and [`ChaseLevDeque`] (the element-at-a-time §6.1.2
//! baseline) are checked op-for-op against a reference `VecDeque` model.
//!
//! Exact sequence equality against the model at every step gives the
//! strong versions of the §4.3 correctness properties at once:
//! exactly-once delivery (pushed ids are unique and every claimed sequence
//! matches the model's), LIFO owner pops, FIFO steals, and overflow
//! refusal without mutation. A separate property pins the monotone
//! [`ContendedWord`] cost accounting: conflicting RMWs on one word
//! complete in strictly increasing simulated time, each paying at least
//! the uncontended atomic cost.

use gtap::coordinator::chaselev::ChaseLevDeque;
use gtap::coordinator::policy::{
    adaptive_amount, SmPool, ADAPTIVE_WARMUP_ATTEMPTS,
};
use gtap::coordinator::queue::{ContendedWord, TaskQueue};
use gtap::coordinator::records::TaskId;
use gtap::coordinator::StealAmount;
use gtap::sim::DeviceSpec;
use gtap::util::prop::{Gen, Runner};
use std::collections::VecDeque;

/// Uniform access to both deque implementations under test.
enum AnyQueue {
    Batched(TaskQueue),
    ChaseLev(ChaseLevDeque),
}

impl AnyQueue {
    fn len(&self) -> usize {
        match self {
            AnyQueue::Batched(q) => q.len(),
            AnyQueue::ChaseLev(q) => q.len(),
        }
    }

    fn push_batch(&mut self, now: u64, ids: &[TaskId], d: &DeviceSpec) -> bool {
        match self {
            AnyQueue::Batched(q) => q.push_batch(now, ids, d).is_some(),
            AnyQueue::ChaseLev(q) => q.push_batch(now, ids, d).is_some(),
        }
    }

    fn pop_batch(&mut self, now: u64, max: usize, out: &mut Vec<TaskId>, d: &DeviceSpec) -> usize {
        match self {
            AnyQueue::Batched(q) => q.pop_batch(now, max, out, d).taken,
            AnyQueue::ChaseLev(q) => q.pop_batch(now, max, out, d).taken,
        }
    }

    fn steal_batch(
        &mut self,
        now: u64,
        max: usize,
        out: &mut Vec<TaskId>,
        d: &DeviceSpec,
    ) -> usize {
        match self {
            AnyQueue::Batched(q) => q.steal_batch(now, max, out, d).taken,
            AnyQueue::ChaseLev(q) => q.steal_batch(now, max, out, d).taken,
        }
    }
}

fn check_against_model(g: &mut Gen, mut q: AnyQueue, cap: usize) {
    let d = DeviceSpec::h100();
    let mut model: VecDeque<TaskId> = VecDeque::new();
    let mut next: TaskId = 0;
    let mut now = 0u64;
    for _ in 0..g.usize(1, 80) {
        now += g.int(0, 500) as u64;
        match g.int(0, 2) {
            0 => {
                let k = g.usize(1, 8);
                let ids: Vec<TaskId> = (0..k as u32).map(|i| next + i).collect();
                let pushed = q.push_batch(now, &ids, &d);
                if model.len() + k <= cap {
                    assert!(pushed, "push within capacity must succeed");
                    model.extend(ids.iter().copied());
                    next += k as u32;
                } else {
                    assert!(!pushed, "push beyond capacity must refuse");
                    assert_eq!(q.len(), model.len(), "failed push must not mutate");
                }
            }
            1 => {
                let max = g.usize(1, 40);
                let mut out = vec![];
                let taken = q.pop_batch(now, max, &mut out, &d);
                let claim = model.len().min(max);
                let want: Vec<TaskId> =
                    (0..claim).map(|_| model.pop_back().unwrap()).collect();
                assert_eq!(taken, claim);
                assert_eq!(out, want, "owner pop must be LIFO, exactly-once");
            }
            _ => {
                let max = g.usize(1, 40);
                let mut out = vec![];
                let taken = q.steal_batch(now, max, &mut out, &d);
                let claim = model.len().min(max);
                let want: Vec<TaskId> =
                    (0..claim).map(|_| model.pop_front().unwrap()).collect();
                assert_eq!(taken, claim);
                assert_eq!(out, want, "steal must be FIFO, exactly-once");
            }
        }
        assert_eq!(q.len(), model.len());
    }
    // final drain matches the model's remaining contents newest-first
    let mut out = vec![];
    q.pop_batch(now, usize::MAX, &mut out, &d);
    let want: Vec<TaskId> = model.iter().rev().copied().collect();
    assert_eq!(out, want, "drain must return exactly the outstanding ids");
}

#[test]
fn taskqueue_batched_ops_match_vecdeque_model() {
    Runner::new().cases(300).run("taskqueue-vs-model", |g| {
        let cap = g.usize(2, 48);
        check_against_model(g, AnyQueue::Batched(TaskQueue::new(cap)), cap);
    });
}

#[test]
fn chaselev_batched_ops_match_vecdeque_model() {
    Runner::new().cases(300).run("chaselev-vs-model", |g| {
        let cap = g.usize(2, 48);
        check_against_model(g, AnyQueue::ChaseLev(ChaseLevDeque::new(cap)), cap);
    });
}

#[test]
fn steal_half_matches_vecdeque_model() {
    // Property: driving a steal-half thief against a queue interleaved
    // with random owner pushes/pops matches the VecDeque model exactly —
    // each steal claims ceil(len/2) (capped at the batch width) of the
    // *oldest* ids — and repeated steal-half drains any backlog in
    // O(log n) steals.
    Runner::new().cases(300).run("steal-half-vs-model", |g| {
        let d = DeviceSpec::h100();
        let cap = g.usize(2, 64);
        let batch_max = g.usize(1, 32);
        let mut q = TaskQueue::new(cap);
        let mut model: VecDeque<TaskId> = VecDeque::new();
        let mut next: TaskId = 0;
        for _ in 0..g.usize(1, 60) {
            match g.int(0, 2) {
                0 => {
                    let k = g.usize(1, 8);
                    let ids: Vec<TaskId> = (0..k as u32).map(|i| next + i).collect();
                    if q.push_batch(0, &ids, &d).is_some() {
                        assert!(model.len() + k <= cap);
                        model.extend(ids.iter().copied());
                        next += k as u32;
                    }
                }
                1 => {
                    let mut out = vec![];
                    q.pop_batch(0, g.usize(1, 8), &mut out, &d);
                    for got in out {
                        assert_eq!(got, model.pop_back().unwrap(), "owner LIFO");
                    }
                }
                _ => {
                    let amount = StealAmount::Half.amount(q.len(), batch_max);
                    assert_eq!(amount, (q.len().div_ceil(2)).clamp(1, batch_max));
                    let mut out = vec![];
                    let taken = q.steal_batch(0, amount, &mut out, &d).taken;
                    let want = model.len().min(amount);
                    assert_eq!(taken, want, "steal-half claims exactly min(amount, len)");
                    for got in out {
                        assert_eq!(got, model.pop_front().unwrap(), "oldest-first");
                    }
                }
            }
            assert_eq!(q.len(), model.len());
        }
        // drain phase: from any backlog, repeated steal-half (uncapped
        // batch) empties the queue in at most log2(len) + 2 steals
        let start_len = q.len();
        let mut steals = 0;
        while !q.is_empty() {
            let amount = StealAmount::Half.amount(q.len(), usize::MAX);
            let mut out = vec![];
            q.steal_batch(0, amount, &mut out, &d);
            for got in out {
                assert_eq!(got, model.pop_front().unwrap());
            }
            steals += 1;
        }
        assert!(model.is_empty());
        let bound = (usize::BITS - start_len.leading_zeros()) as usize + 2;
        assert!(
            steals <= bound,
            "steal-half took {steals} steals for {start_len} tasks (bound {bound})"
        );
    });
}

#[test]
fn sm_tier_pool_matches_vecdeque_model() {
    // Property: the per-SM tier pool is an independent FIFO per SM —
    // spilled batches come back out oldest-first, a batch that does not
    // fit is refused without mutation, and SMs never alias.
    Runner::new().cases(300).run("sm-pool-vs-model", |g| {
        let d = DeviceSpec::h100();
        let sms = g.usize(1, 4);
        let cap = g.usize(2, 32);
        let mut pool = SmPool::new(sms, cap);
        let mut models: Vec<VecDeque<TaskId>> = vec![VecDeque::new(); sms];
        let mut next: TaskId = 0;
        for _ in 0..g.usize(1, 80) {
            let sm = g.usize(0, sms - 1);
            if g.chance(0.5) {
                // spill a batch
                let k = g.usize(1, 6);
                let ids: Vec<TaskId> = (0..k as u32).map(|i| next + i).collect();
                let pushed = pool.push(sm, 0, &ids, &d).is_some();
                if models[sm].len() + k <= cap {
                    assert!(pushed, "spill within capacity must succeed");
                    models[sm].extend(ids.iter().copied());
                    next += k as u32;
                } else {
                    assert!(!pushed, "overfull spill must be refused");
                }
            } else {
                // a same-SM worker drains the pool
                let max = g.usize(1, 8);
                let mut out = vec![];
                let taken = pool.pop(sm, 0, max, &mut out, &d).taken;
                let claim = models[sm].len().min(max);
                let want: Vec<TaskId> =
                    (0..claim).map(|_| models[sm].pop_front().unwrap()).collect();
                assert_eq!(taken, claim);
                assert_eq!(out, want, "pool drain must be FIFO, exactly-once");
            }
            for s in 0..sms {
                assert_eq!(pool.len(s), models[s].len(), "sm {s} diverged");
                assert_eq!(pool.free(s), cap.max(2) - models[s].len());
            }
        }
        assert_eq!(
            pool.total_len(),
            models.iter().map(|m| m.len()).sum::<usize>()
        );
    });
}

#[test]
fn sm_pool_modeled_pricing_matches_the_bank_model() {
    // Property: under MemSysMode::Modeled every pool op's cycles equal the
    // shared-memory bank model evaluated at the pool's monotone ring
    // positions, and the pool's conflict counter is exactly the running
    // sum of per-op conflicts. (Flat pricing is covered by the golden
    // pins; this pins the modeled replacement op for op.)
    use gtap::sim::memsys::{bank, MemSysMode};
    Runner::new().cases(200).run("sm-pool-bank-pricing", |g| {
        let d = DeviceSpec::h100();
        let cap = g.usize(2, 70);
        let mut pool = SmPool::with_mode(1, cap, MemSysMode::Modeled);
        let mut pushed = 0u64;
        let mut popped = 0u64;
        let mut conflicts = 0u64;
        let mut len = 0usize;
        for _ in 0..g.usize(1, 60) {
            if g.chance(0.5) {
                let k = g.usize(1, 8);
                let ids: Vec<TaskId> = (0..k as u32).collect();
                if let Some(op) = pool.push(0, 0, &ids, &d) {
                    let (cycles, c) = bank::smem_op_cycles(&d, pushed, k, cap.max(2));
                    assert_eq!(op.cycles, cycles, "push at position {pushed}");
                    pushed += k as u64;
                    conflicts += c;
                    len += k;
                } else {
                    assert!(len + k > cap.max(2), "refusal only on overflow");
                }
            } else {
                let max = g.usize(1, 8);
                let mut out = vec![];
                let op = pool.pop(0, 0, max, &mut out, &d);
                let (cycles, c) = bank::smem_op_cycles(&d, popped, op.taken, cap.max(2));
                assert_eq!(op.cycles, cycles, "pop at position {popped}");
                popped += op.taken as u64;
                conflicts += c;
                len -= op.taken;
            }
            assert_eq!(pool.len(0), len);
        }
        assert_eq!(pool.bank_conflicts(), conflicts);
    });
}

#[test]
fn adaptive_steal_controller_is_monotone_and_victim_bounded() {
    // Properties of the adaptive steal-amount controller: the claim stays
    // in [1, batch_max] and never exceeds the victim's visible backlog
    // (modulo the ≥1 livelock floor), and — for a fixed victim — a higher
    // observed failure rate never steals *more*.
    Runner::new().cases(500).run("adaptive-steal", |g| {
        let batch = g.usize(1, 32);
        let len = g.usize(0, 100);
        let attempts = g.int(0, 1000) as u64;
        let ok_lo = g.int(0, attempts as i64) as u64;
        let ok_hi = g.int(ok_lo as i64, attempts as i64) as u64;
        let more_failures = adaptive_amount(attempts, ok_lo, len, batch);
        let fewer_failures = adaptive_amount(attempts, ok_hi, len, batch);
        for a in [more_failures, fewer_failures] {
            assert!(a >= 1, "a steal that asks for nothing would livelock");
            assert!(a <= batch, "never exceeds the batch width");
            assert!(a <= len.max(1), "never exceeds the victim's length");
        }
        assert!(
            more_failures <= fewer_failures,
            "response must be monotone in the failure rate: \
             {more_failures} > {fewer_failures} \
             (attempts {attempts}, ok {ok_lo}/{ok_hi}, len {len}, batch {batch})"
        );
        // past warm-up with total failure, the controller halves
        let starved = adaptive_amount(ADAPTIVE_WARMUP_ATTEMPTS, 0, len, batch);
        assert_eq!(starved, len.div_ceil(2).clamp(1, batch));
    });
}

#[test]
fn contended_word_cost_accounting_is_monotone() {
    Runner::new().cases(200).run("contended-word-monotone", |g| {
        let d = DeviceSpec::h100();
        let mut w = ContendedWord::default();
        let mut now = 0u64;
        let mut last_completion = 0u64;
        for _ in 0..g.usize(1, 60) {
            // arrival times never run backwards; frequently collide exactly
            now += if g.chance(0.4) { 0 } else { g.int(1, 2000) as u64 };
            let cycles = if g.chance(0.5) {
                w.access(now, &d)
            } else {
                w.access_window(now, &d, g.int(1, 600) as u64)
            };
            assert!(
                cycles >= d.atomic,
                "every access pays at least the uncontended RMW"
            );
            let completion = now + cycles;
            assert!(
                completion > last_completion,
                "conflicting RMWs must serialize in strictly increasing time \
                 ({completion} vs {last_completion})"
            );
            last_completion = completion;
        }
    });
}
