//! Golden tests pinning the gtapc transformation against the paper's
//! examples: Program 1 (mergesort state machine), Program 4/6 (fib
//! task-data layout + switch), Program 5 (block-level BFS).

use gtap::compiler::{compile_default, pretty};
use gtap::workloads::{bfs, fib, sort};

#[test]
fn mergesort_becomes_two_state_machine() {
    // Program 1: case 0 = split/spawn/wait, case 1 = merge
    let m = compile_default(&sort::mergesort_source(128)).unwrap();
    let f = m.func(m.func_id("msort").unwrap());
    assert_eq!(f.num_states(), 2);
    let text = pretty::render_func(f);
    assert!(text.contains("case 0:"), "{text}");
    assert!(text.contains("case 1:"), "{text}");
    assert!(text.contains("__gtap_prepare_for_join(next_state=1"), "{text}");
    // mid crosses the taskwait: it must be spilled (cf. Program 1's t->mid)
    assert!(f.layout.offset_of("mid").is_some(), "{text}");
    // the merge intrinsic runs in the post-join state
    let entry1 = f.state_entries[1] as usize;
    let post_join = &f.insns[entry1..];
    assert!(post_join
        .iter()
        .any(|i| matches!(i, gtap::ir::Insn::Intr { id: gtap::ir::Intrinsic::MergeSerial, .. })));
}

#[test]
fn fib_task_data_matches_program6() {
    let m = compile_default(&fib::source(0, true)).unwrap();
    let f = m.func(m.func_id("fib").unwrap());
    let text = pretty::render_func(f);
    // struct fib_task_data { int __cap_n; __cap_a; __cap_b; __cap_result }
    assert!(text.contains("struct fib_task_data"), "{text}");
    for field in ["__cap_n", "__cap_a", "__cap_b", "__cap___result"] {
        assert!(text.contains(field), "missing {field} in:\n{text}");
    }
    assert!(text.contains("__gtap_load_result(0)"), "{text}");
    assert!(text.contains("__gtap_load_result(1)"), "{text}");
    assert_eq!(f.layout.words(), 4);
}

#[test]
fn bfs_compiles_block_level_with_parfor() {
    let m = compile_default(&bfs::source()).unwrap();
    let f = m.func(m.func_id("bfs").unwrap());
    assert!(f.uses_parfor);
    assert!(!f.has_taskwait, "Program 5 is spawn-only");
    assert_eq!(f.num_states(), 1);
}

#[test]
fn cilksort_task_functions_state_counts() {
    let m = compile_default(&sort::cilksort_source(64, 256, true)).unwrap();
    let cs = m.func(m.func_id("csort").unwrap());
    assert_eq!(cs.num_states(), 4, "three taskwaits: sorts, merge, copy-back");
    let cm = m.func(m.func_id("cmerge").unwrap());
    assert_eq!(cm.num_states(), 3, "one taskwait per split branch");
    let pc = m.func(m.func_id("pcopy").unwrap());
    assert_eq!(pc.num_states(), 2, "parallel copy joins its two halves");
}

#[test]
fn priority_clause_renders_and_compile_render_is_deterministic() {
    // PR 3 added `#pragma gtap task priority(expr)`; the Program-6 view
    // must disassemble it (spawns with the clause show `priority=r<reg>`,
    // spawns without it stay clean — the inherit sentinel is not a
    // register). The examples' compile→render round trip relies on this
    // being total and deterministic: compiling the same source twice must
    // produce byte-identical renders.
    let src = r#"
        #pragma gtap function
        int fib(int n) {
            if (n < 2) return n;
            int a; int b;
            #pragma gtap task queue(1) priority(n - 1)
            a = fib(n - 1);
            #pragma gtap task queue(1)
            b = fib(n - 2);
            #pragma gtap taskwait queue(2)
            return a + b;
        }
    "#;
    let m1 = compile_default(src).unwrap();
    let text = pretty::render_module(&m1);
    assert!(
        text.contains("priority=r"),
        "annotated spawn must render its priority register:\n{text}"
    );
    let spawn_lines: Vec<&str> = text.lines().filter(|l| l.contains("spawn func#")).collect();
    assert_eq!(spawn_lines.len(), 2, "{text}");
    assert!(
        spawn_lines[0].contains("priority=r"),
        "first spawn carries the clause: {}",
        spawn_lines[0]
    );
    assert!(
        !spawn_lines[1].contains("priority"),
        "unannotated spawn must not print the inherit sentinel: {}",
        spawn_lines[1]
    );
    // compile → render is deterministic (idempotent pipeline)
    let m2 = compile_default(src).unwrap();
    assert_eq!(text, pretty::render_module(&m2));
}

#[test]
fn nested_taskwaits_unique_states() {
    let src = r#"
        #pragma gtap function
        void leaf(int x) { print_int(x); }
        #pragma gtap function
        void phases(int n) {
            int i = 0;
            while (i < n) {
                #pragma gtap task
                leaf(i);
                #pragma gtap taskwait
                i = i + 1;
            }
            #pragma gtap task
            leaf(n);
            #pragma gtap taskwait
        }
    "#;
    let m = compile_default(src).unwrap();
    let f = m.func(m.func_id("phases").unwrap());
    assert_eq!(f.num_states(), 3, "each taskwait gets a unique state");
    // re-entry into the loop must work: i is spilled
    assert!(f.layout.offset_of("i").is_some());
}
