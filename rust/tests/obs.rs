//! Observability contract suite (ARCHITECTURE.md "Observability").
//!
//! The load-bearing invariant: observability charges **zero simulated
//! cycles**. Arming any sink — the Chrome-trace `Tracer`, the
//! `MetricsRegistry`, or both fanned out — must leave `RunStats`
//! byte-identical to the unarmed run on every workload × policy ×
//! memory-model × fault-plan combination, because every hook fires
//! after its costs were already charged and sampling only reads state.
//!
//! On top of byte-identity this suite pins trace well-formedness
//! (monotone per-track timestamps, balanced `B`/`E` pairs, spawn/finish
//! conservation on clean runs) and the service engine's per-round
//! metrics snapshots (one per round; deltas sum back to the cumulative
//! accounting).

use std::collections::HashMap;

use gtap::coordinator::{FaultPlan, Granularity, GtapConfig, RunStats, Session};
use gtap::ir::types::Value;
use gtap::obs::metrics::MetricsRegistry;
use gtap::obs::trace::{Fanout, NoTrace, TraceEvent, TraceSink, Tracer};
use gtap::runtime::service::{
    AdmissionPolicy, ResilienceConfig, ServiceEngine, SubmitOpts,
};
use gtap::sim::profile::Profiler;
use gtap::sim::{DeviceSpec, MemSysMode};
use gtap::workloads::{bfs, fib, tree};

/// Run one workload to completion under `cfg` with the given sink,
/// building a fresh session each time (no state carries over between
/// the unarmed and armed runs).
fn run_wl<S: TraceSink>(wl: &str, cfg: &GtapConfig, epaq: bool, sink: &mut S) -> RunStats {
    let dev = DeviceSpec::h100();
    match wl {
        "fib" => {
            let mut s = Session::compile(&fib::source(0, epaq), cfg.clone(), dev).unwrap();
            s.run_with("fib", &[Value::from_i64(12)], None, sink).unwrap()
        }
        "tree" => {
            let mut s =
                Session::compile(&tree::full_tree_source(4, 8), cfg.clone(), dev).unwrap();
            let acc = s.alloc(1);
            s.run_with(
                "tree",
                &[Value::from_i64(6), Value::from_i64(7), Value(acc)],
                None,
                sink,
            )
            .unwrap()
        }
        "bfs" => {
            let g = bfs::CsrGraph::random(80, 3, 5);
            let mut s = Session::compile(&bfs::source(), cfg.clone(), dev).unwrap();
            let ro = s.alloc(g.row_offsets.len() as u64);
            let ci = s.alloc(g.col_indices.len().max(1) as u64);
            let dp = s.alloc(g.n as u64);
            s.memory.write_i64s(ro, &g.row_offsets);
            s.memory.write_i64s(ci, &g.col_indices);
            s.memory.write_i64s(dp, &vec![i64::MAX; g.n]);
            s.memory.store(dp, 0);
            s.run_with(
                "bfs",
                &[Value::from_i64(0), Value(ro), Value(ci), Value(dp)],
                None,
                sink,
            )
            .unwrap()
        }
        other => panic!("unknown workload {other}"),
    }
}

/// Base config per workload (bfs is the paper's block-level Program 5).
fn base_cfg(wl: &str) -> GtapConfig {
    match wl {
        "bfs" => GtapConfig {
            grid_size: 4,
            block_size: 64,
            granularity: Granularity::Block,
            assume_no_taskwait: true,
            ..Default::default()
        },
        _ => GtapConfig {
            grid_size: 4,
            block_size: 32,
            ..Default::default()
        },
    }
}

/// Structural checks on an armed trace: per-track monotone timestamps,
/// balanced `B`/`E` pairs (depth never negative, zero at the end), and
/// — on clean runs (no faults, no eviction, no drain) — every spawn
/// matched by exactly one finish.
fn assert_well_formed(tr: &Tracer, stats: &RunStats, clean: bool, label: &str) {
    let evs = tr.chrome_events();
    assert!(!evs.is_empty(), "{label}: empty trace");
    let mut last_ts: HashMap<u64, u64> = HashMap::new();
    let mut depth: HashMap<u64, i64> = HashMap::new();
    for e in &evs {
        let l = last_ts.entry(e.tid).or_insert(0);
        assert!(
            e.ts >= *l,
            "{label}: track {} goes backwards ({} after {})",
            e.tid,
            e.ts,
            l
        );
        *l = e.ts;
        match e.ph {
            'B' => *depth.entry(e.tid).or_insert(0) += 1,
            'E' => {
                let d = depth.entry(e.tid).or_insert(0);
                *d -= 1;
                assert!(*d >= 0, "{label}: E without B on track {}", e.tid);
            }
            'i' | 'C' | 'M' => {}
            other => panic!("{label}: unexpected phase {other:?}"),
        }
    }
    for (tid, d) in depth {
        assert_eq!(d, 0, "{label}: unbalanced B/E on track {tid}");
    }
    if clean {
        let spawns = tr
            .events()
            .iter()
            .filter(|e| matches!(e, TraceEvent::Spawn { .. }))
            .count() as u64;
        let finishes = tr
            .events()
            .iter()
            .filter(|e| matches!(e, TraceEvent::Finish { .. }))
            .count() as u64;
        assert_eq!(
            spawns, finishes,
            "{label}: every spawn needs a matching finish on a clean run"
        );
        assert_eq!(finishes, stats.tasks_finished, "{label}: finish events vs counter");
    }
    // The JSON export is one object; deep validation happens in CI via
    // `python3 -m json.tool`, here we pin the envelope.
    let json = tr.to_chrome_trace();
    assert!(json.starts_with("{\"traceEvents\":["), "{label}: bad envelope");
    assert!(json.ends_with('}'), "{label}: bad envelope tail");
    assert!(!json.contains('\n'), "{label}: trace JSON is a single line");
}

/// The tentpole sweep: tracing-on must be byte-identical to tracing-off
/// across fib/tree/bfs × default/recommended/EPAQ × flat/modeled ×
/// faults off/on, and every armed trace must be structurally sound.
#[test]
fn trace_on_is_byte_identical_across_the_matrix() {
    let fault_plan = "stall@50:w0:40;kill@400:w1";
    for wl in ["fib", "tree", "bfs"] {
        for pol in ["default", "recommended", "epaq"] {
            for ms in ["flat", "modeled"] {
                for faults in [None, Some(fault_plan)] {
                    let mut cfg = base_cfg(wl);
                    let mut epaq = false;
                    match pol {
                        "default" => {}
                        "recommended" => {
                            cfg.policy = gtap::coordinator::PolicyConfig::recommended();
                        }
                        "epaq" => {
                            cfg.num_queues = 3;
                            epaq = wl == "fib";
                        }
                        _ => unreachable!(),
                    }
                    cfg.memsys = MemSysMode::parse(ms).unwrap();
                    if let Some(sp) = faults {
                        cfg.faults = FaultPlan::parse(sp).unwrap();
                    }
                    let label = format!(
                        "{wl}/{pol}/{ms}/faults={}",
                        if faults.is_some() { "on" } else { "off" }
                    );
                    let base = run_wl(wl, &cfg, epaq, &mut NoTrace);
                    let mut tr = Tracer::new();
                    let traced = run_wl(wl, &cfg, epaq, &mut tr);
                    assert_eq!(base, traced, "{label}: tracing perturbed the run");
                    let clean = faults.is_none() && !base.drained;
                    assert_well_formed(&tr, &base, clean, &label);
                }
            }
        }
    }
}

/// A metrics registry (SAMPLING on, so the scheduler also walks queues
/// for interval samples) must not perturb the run either, and its
/// counters must agree with the scheduler's own `RunStats`.
#[test]
fn metrics_registry_is_byte_identical_and_coherent() {
    let cfg = base_cfg("fib");
    let base = run_wl("fib", &cfg, false, &mut NoTrace);
    let mut m = MetricsRegistry::new();
    let armed = run_wl("fib", &cfg, false, &mut m);
    assert_eq!(base, armed, "metrics sampling perturbed the run");
    assert_eq!(m.finishes.get(), base.tasks_finished);
    assert_eq!(m.steals_ok.get(), base.steals_ok);
    assert_eq!(m.steal_attempts.get(), base.steal_attempts);
    assert_eq!(m.sm_spills.get(), base.sm_spills);
    assert_eq!(m.sm_pool_hits.get(), base.sm_pool_hits);
    assert!(!m.series.is_empty(), "interval sampling produced no points");
    let json = m.to_json();
    assert!(json.starts_with("{\"counters\":{"), "metrics JSON envelope");
    assert!(json.contains("\"seg_latency\":["));
}

/// Profiler + Tracer fanned out together (the `Exec::traced().profiled()`
/// path) still charges nothing.
#[test]
fn fanout_of_profiler_and_tracer_is_byte_identical() {
    let cfg = base_cfg("tree");
    let base = run_wl("tree", &cfg, false, &mut NoTrace);
    let mut prof = Profiler::enabled();
    let mut tr = Tracer::new();
    let armed = run_wl("tree", &cfg, false, &mut Fanout(&mut prof, &mut tr));
    assert_eq!(base, armed);
    assert!(!prof.events.is_empty(), "profiler half saw the iterations");
    assert!(!tr.is_empty(), "tracer half recorded events");
}

fn service_cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 64,
        granularity: Granularity::Block,
        ..Default::default()
    }
}

fn run_service(observe: bool, resil: Option<ResilienceConfig>, deadline: Option<u64>) -> ServiceEngine {
    let mut eng = ServiceEngine::new(
        service_cfg(),
        DeviceSpec::h100(),
        AdmissionPolicy::parse("fair").unwrap(),
    )
    .unwrap();
    if let Some(r) = resil {
        eng.set_resilience(r);
    }
    if observe {
        eng.enable_tracing();
        eng.enable_metrics();
    }
    let t = eng.open_session("fib", &fib::source(0, false)).unwrap();
    for _ in 0..3 {
        eng.submit(
            t,
            "fib",
            &[Value::from_i64(10)],
            SubmitOpts {
                deadline,
                ..Default::default()
            },
        )
        .unwrap();
    }
    eng.run_to_idle().unwrap();
    eng
}

/// Service rounds with tracing + metrics armed resolve byte-identically
/// to unarmed rounds, and the metrics stream carries exactly one
/// snapshot per round whose deltas sum back to the cumulative
/// accounting.
#[test]
fn service_observability_is_transparent_and_snapshots_per_round() {
    let mut armed = run_service(true, None, None);
    let mut plain = run_service(false, None, None);
    assert_eq!(armed.take_outcomes(), plain.take_outcomes());
    assert!(plain.take_trace().is_none());
    assert!(plain.take_metrics().is_empty());

    let rounds = armed.rounds();
    let acct = armed.accounting(0).clone();
    let snaps = armed.take_metrics();
    assert_eq!(snaps.len() as u64, rounds, "one snapshot per round");
    for (i, s) in snaps.iter().enumerate() {
        assert_eq!(s.round, i as u64);
        assert_eq!(s.ended - s.started, s.cycles);
        assert_eq!(s.tenants.len(), 1);
        let j = s.to_json();
        assert!(j.starts_with('{') && j.ends_with('}') && !j.contains('\n'));
        assert!(j.contains("\"name\":\"fib\""));
    }
    let sum = |f: fn(&gtap::obs::metrics::TenantRound) -> u64| -> u64 {
        snaps.iter().map(|s| f(&s.tenants[0])).sum()
    };
    assert_eq!(sum(|t| t.completed), acct.jobs_completed);
    assert_eq!(sum(|t| t.tasks_finished), acct.tasks_finished);
    assert_eq!(sum(|t| t.spawns), acct.spawns);
    assert_eq!(sum(|t| t.retried), 0);

    let tr = armed.take_trace().expect("tracing was armed");
    assert!(
        tr.events()
            .iter()
            .any(|e| matches!(e, TraceEvent::Service { kind: "admit", .. })),
        "service trace carries admission events"
    );
}

/// The resilience taxonomy (retries, failures, quarantine) flows into
/// the snapshots: a sub-startup deadline evicts every attempt with zero
/// progress, so retry-on ends in quarantine and the per-round deltas
/// still sum to the accounting.
#[test]
fn service_snapshots_carry_resilience_taxonomy() {
    let resil = ResilienceConfig {
        retry: true,
        ..Default::default()
    };
    let mut armed = run_service(true, Some(resil), Some(0));
    let mut plain = run_service(false, Some(resil), Some(0));
    assert_eq!(armed.take_outcomes(), plain.take_outcomes());

    let acct = armed.accounting(0).clone();
    let snaps = armed.take_metrics();
    assert!(!snaps.is_empty());
    let sum = |f: fn(&gtap::obs::metrics::TenantRound) -> u64| -> u64 {
        snaps.iter().map(|s| f(&s.tenants[0])).sum()
    };
    assert_eq!(sum(|t| t.retried), acct.jobs_retried);
    // Quarantine sweeps can resolve pending jobs between rounds (and in
    // run_to_idle's final sweep), outside any snapshot — so failures are
    // bounded by, not equal to, the cumulative accounting.
    assert!(sum(|t| t.failed) <= acct.jobs_failed);
    assert_eq!(sum(|t| t.evicted), acct.jobs_evicted);
    assert!(acct.jobs_retried > 0, "deadline evictions must retry");
    assert!(
        snaps.last().unwrap().tenants[0].quarantined,
        "zero-progress deterministic failures open the breaker"
    );
    let tr = armed.take_trace().expect("tracing was armed");
    let kinds: Vec<&str> = tr
        .events()
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Service { kind, .. } => Some(*kind),
            _ => None,
        })
        .collect();
    assert!(kinds.contains(&"retry"), "retry events traced: {kinds:?}");
    assert!(
        kinds.contains(&"quarantine"),
        "quarantine event traced: {kinds:?}"
    );
}
