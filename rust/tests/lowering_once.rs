//! Regression pin for the per-run relowering bug.
//!
//! `Session::run_with` used to rebuild the scheduler's lowering pipeline
//! (decode → superblock-fuse → trace-fuse) on *every* submission. The fix
//! lowers once at session/service construction ([`gtap::ir::LoweredModule`])
//! and lets every run borrow the cached bundle. This suite counts
//! `TracedModule::build` invocations — the final, most expensive lowering
//! stage — around the APIs to pin the contract, and pins that a reused
//! session's second run is byte-identical to a fresh session's first.
//!
//! NOTE: the counter is process-wide, so every delta assertion lives in
//! this single `#[test]` — this file must stay a one-test binary (tests
//! within a binary run in parallel and would race the counter).

use gtap::coordinator::{GtapConfig, Scheduler, Session};
use gtap::ir::traced::build_count;
use gtap::ir::types::Value;
use gtap::ir::LoweredModule;
use gtap::runtime::service::{AdmissionPolicy, ServiceEngine, SubmitOpts};
use gtap::sim::profile::Profiler;
use gtap::sim::{DeviceSpec, Memory};

const FIB: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

fn cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 32,
        ..Default::default()
    }
}

#[test]
fn lowering_happens_once_per_module_never_per_run() {
    let dev = DeviceSpec::h100();

    // --- Session: one lowering at construction, zero per run ---------
    let c0 = build_count();
    let mut sess = Session::compile(FIB, cfg(), dev.clone()).unwrap();
    let c1 = build_count();
    assert_eq!(c1 - c0, 1, "session construction lowers exactly once");
    let run1 = sess.run("fib", &[Value::from_i64(12)]).unwrap();
    let run2 = sess.run("fib", &[Value::from_i64(12)]).unwrap();
    assert_eq!(
        build_count(),
        c1,
        "repeated Session::run must not relower (the fixed bug)"
    );
    // Reuse is also semantically clean: run 2 of a warm session is
    // byte-identical to run 1 of a session rebuilt from scratch.
    let mut fresh = Session::compile(FIB, cfg(), dev.clone()).unwrap();
    let fresh1 = fresh.run("fib", &[Value::from_i64(12)]).unwrap();
    assert_eq!(run2, fresh1, "warm run 2 == cold run 1, byte for byte");
    assert_eq!(run1, run2, "same session, same submission, same stats");

    // --- raw Scheduler: borrows a bundle, never builds one -----------
    let config = cfg();
    let c2 = build_count();
    let lowered = sess.lowered();
    for _ in 0..3 {
        let mut mem = Memory::new(lowered.module.globals_words());
        let mut prof = Profiler::disabled();
        let mut s = Scheduler::new(&lowered, &config, &dev).unwrap();
        s.spawn_root("fib", &[Value::from_i64(10)]).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap();
    }
    assert_eq!(build_count(), c2, "Scheduler::new does no lowering at all");

    // --- explicit lower: exactly one build per call ------------------
    let c3 = build_count();
    let _bundle = LoweredModule::lower(sess.module().clone(), &dev);
    assert_eq!(build_count() - c3, 1);

    // --- service engine: one lowering per distinct content, zero on
    // warm sessions and zero per round ---------------------------------
    let c4 = build_count();
    let mut eng = ServiceEngine::new(cfg(), dev, AdmissionPolicy::FairShare).unwrap();
    let a = eng.open_session("a", FIB).unwrap();
    let b = eng.open_session("b", FIB).unwrap();
    assert_eq!(
        build_count() - c4,
        1,
        "two sessions over the same content share one lowering"
    );
    for _ in 0..2 {
        eng.submit(a, "fib", &[Value::from_i64(11)], SubmitOpts::default())
            .unwrap();
        eng.submit(b, "fib", &[Value::from_i64(10)], SubmitOpts::default())
            .unwrap();
    }
    eng.run_to_idle().unwrap();
    assert_eq!(
        build_count() - c4,
        1,
        "warm submissions and rounds do no relowering"
    );
    assert_eq!(eng.cache_stats(), (1, 1), "one miss (a), one hit (b)");
}
