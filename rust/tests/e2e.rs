//! Cross-module integration tests: scheduler-policy equivalence,
//! determinism, feasibility errors, performance-shape assertions, and the
//! XLA payload path.

use gtap::bench::runners::{self, Exec};
use gtap::coordinator::SchedulerKind;
use gtap::util::prop::Runner;
use gtap::workloads::tree;

#[test]
fn all_policies_agree_on_every_workload() {
    for kind in [
        SchedulerKind::WorkStealing,
        SchedulerKind::GlobalQueue,
        SchedulerKind::SequentialChaseLev,
    ] {
        let e = Exec::gpu_thread(8, 32).scheduler(kind);
        runners::run_fib(&e, 14, 0, false).unwrap();
        runners::run_nqueens(&e.clone().no_taskwait(), 8, 4, false).unwrap();
        runners::run_mergesort(&e, 800, 32, 7).unwrap();
        runners::run_cilksort(&e, 800, 32, 64, false, 7).unwrap();
        runners::run_full_tree(&e, 6, 4, 8, None).unwrap();
    }
}

#[test]
fn simulated_time_deterministic_per_seed_and_varies_across_seeds() {
    let run = |seed| {
        runners::run_fib(&Exec::gpu_thread(16, 32).seed(seed), 16, 0, false)
            .unwrap()
            .stats
            .cycles
    };
    assert_eq!(run(1), run(1));
    assert_ne!(run(1), run(2), "victim selection must differ across seeds");
}

#[test]
fn queue_overflow_is_reported_not_hung() {
    let e = Exec::gpu_thread(1, 32).queue_capacity(8);
    let err = match runners::run_fib(&e, 18, 0, false) {
        Err(e) => e,
        Ok(_) => panic!("expected overflow error"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("GTAP_MAX_TASKS") || msg.contains("overflow") || msg.contains("pool"),
        "{msg}"
    );
}

#[test]
fn work_stealing_beats_global_queue_at_scale() {
    // Fig. 3's headline shape at a mid-size point
    let ws = runners::run_fib(&Exec::gpu_thread(128, 32), 20, 0, false)
        .unwrap()
        .seconds;
    let gq = runners::run_fib(
        &Exec::gpu_thread(128, 32).scheduler(SchedulerKind::GlobalQueue),
        20,
        0,
        false,
    )
    .unwrap()
    .seconds;
    assert!(gq > ws, "global queue {gq} must be slower than WS {ws}");
}

#[test]
fn more_workers_help_until_saturation() {
    let t = |grid| {
        runners::run_fib(&Exec::gpu_thread(grid, 32), 20, 0, false)
            .unwrap()
            .seconds
    };
    let (t1, t16) = (t(1), t(16));
    assert!(t16 < t1 / 3.0, "16x workers must speed up: {t1} vs {t16}");
}

#[test]
fn gpu_beats_cpu_on_compute_heavy_tree() {
    // needs enough tasks to cover GPU startup + fill warps (§6.3: GTaP
    // wins as problem size grows)
    let gpu = runners::run_full_tree(&Exec::gpu_thread(128, 64), 14, 16, 2048, None)
        .unwrap()
        .seconds;
    let cpu = runners::run_full_tree(&Exec::cpu72(), 14, 16, 2048, None)
        .unwrap()
        .seconds;
    assert!(gpu < cpu, "gpu {gpu} vs cpu {cpu}");
}

#[test]
fn cpu_beats_gpu_on_mergesort_at_scale() {
    // the §6.2 negative result
    let gpu = runners::run_mergesort(&Exec::gpu_thread(128, 32), 1 << 14, 128, 3)
        .unwrap()
        .seconds;
    let cpu = runners::run_mergesort(&Exec::cpu72(), 1 << 14, 4096, 3)
        .unwrap()
        .seconds;
    assert!(cpu < gpu, "cpu {cpu} must beat gpu {gpu} on mergesort");
}

#[test]
fn block_level_wins_thin_trees_with_heavy_tasks() {
    // Fig. 8's reversal: pruned tree + large per-task work
    let thread = runners::run_pruned_tree(&Exec::gpu_thread(128, 64), 14, 64, 4096, 5)
        .unwrap()
        .seconds;
    let block = runners::run_pruned_tree(&Exec::gpu_block(128, 64), 14, 64, 4096, 5)
        .unwrap()
        .seconds;
    assert!(
        block < thread,
        "block {block} should beat thread {thread} on the thin tree"
    );
}

#[test]
fn prop_random_tree_checksums_match_reference() {
    Runner::new().cases(12).run("random-trees", |g| {
        let depth = g.int(2, 7);
        let mem = g.int(0, 16);
        let comp = g.int(0, 32);
        let seed = g.int(1, 1 << 20);
        let e = Exec::gpu_thread(g.usize(1, 8), 32).seed(g.rng().next_u64());
        let out = runners::run_pruned_tree(&e, depth, mem, comp, seed).unwrap();
        // run_pruned_tree validates internally; also sanity-check counts
        let (_, want_tasks) = tree::pruned_tree_reference(depth, seed, mem, comp);
        assert_eq!(out.stats.tasks_finished, want_tasks);
    });
}

#[test]
fn prop_random_sorts() {
    Runner::new().cases(10).run("random-sorts", |g| {
        let n = g.usize(2, 2000);
        let cutoff = *g.choose(&[4i64, 16, 64, 256]);
        let e = Exec::gpu_thread(g.usize(1, 8), 32).seed(g.rng().next_u64());
        if g.chance(0.5) {
            runners::run_mergesort(&e, n, cutoff, g.rng().next_u64()).unwrap();
        } else {
            runners::run_cilksort(&e, n, cutoff, cutoff * 2, g.chance(0.5), g.rng().next_u64())
                .unwrap();
        }
    });
}

#[test]
fn xla_payload_engine_end_to_end() {
    let Ok(mut engine) = gtap::runtime::XlaPayloadEngine::from_artifacts() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let out = runners::run_full_tree(&Exec::gpu_thread(8, 32), 6, 8, 16, Some(&mut engine))
        .unwrap();
    assert_eq!(out.stats.tasks_finished, 127);
    assert!(engine.executions > 0);
    assert_eq!(engine.lane_payloads, 127);
    // simulated time must be engine-independent
    let native = runners::run_full_tree(&Exec::gpu_thread(8, 32), 6, 8, 16, None).unwrap();
    assert_eq!(out.stats.cycles, native.stats.cycles);
}

#[test]
fn epaq_helps_at_paper_scale() {
    if std::env::var("GTAP_SLOW_TESTS").ok().as_deref() != Some("1") {
        eprintln!("skipping (set GTAP_SLOW_TESTS=1): ~20s");
        return;
    }
    let one = runners::run_fib(&Exec::gpu_thread(4000, 32).queues(1), 38, 10, false)
        .unwrap()
        .seconds;
    let epaq = runners::run_fib(&Exec::gpu_thread(4000, 32).queues(3), 38, 10, true)
        .unwrap()
        .seconds;
    assert!(epaq < one, "EPAQ {epaq} must beat 1-queue {one} at scale");
}
