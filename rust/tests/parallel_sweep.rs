//! Acceptance property for the parallel bench harness: a fig3-style sweep
//! (grid-size points x seeded repetitions over a real workload runner)
//! produces **byte-identical** series whether it runs on 1 thread or many.
//!
//! Kept as a single test: it owns the GTAP_BENCH_* environment for the
//! duration of this binary.

use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::measure_curve;
use gtap::coordinator::SchedulerKind;
use gtap::util::stats::Summary;

fn fig3_style_sweep() -> Vec<(usize, Summary)> {
    let grids: Vec<usize> = vec![1, 2, 4, 8];
    measure_curve(&grids, |&g, seed| {
        runners::run_fib(
            &Exec::gpu_thread(g, 32)
                .scheduler(SchedulerKind::WorkStealing)
                .seed(seed),
            11,
            0,
            false,
        )
        .unwrap()
        .seconds
    })
}

#[test]
fn parallel_sweep_is_bit_identical_to_serial() {
    std::env::set_var("GTAP_BENCH_RUNS", "3");

    std::env::set_var("GTAP_BENCH_THREADS", "1");
    let serial = fig3_style_sweep();

    std::env::set_var("GTAP_BENCH_THREADS", "5");
    let parallel = fig3_style_sweep();

    std::env::remove_var("GTAP_BENCH_THREADS");
    std::env::remove_var("GTAP_BENCH_RUNS");

    assert_eq!(serial.len(), parallel.len());
    for ((xa, sa), (xb, sb)) in serial.iter().zip(parallel.iter()) {
        assert_eq!(xa, xb);
        assert_eq!(sa.n, sb.n);
        for (a, b) in [
            (sa.median, sb.median),
            (sa.q1, sb.q1),
            (sa.q3, sb.q3),
            (sa.min, sb.min),
            (sa.max, sb.max),
            (sa.mean, sb.mean),
        ] {
            assert_eq!(a.to_bits(), b.to_bits(), "series diverged at grid {xa}");
        }
    }
}
