//! Golden equivalence: the policy-layer refactor must not change behavior.
//!
//! `coordinator::scheduler_ref::RefScheduler` is the pre-refactor
//! monolithic scheduler, kept verbatim. For every policy combination the
//! old monolith could express — the default, locality-aware stealing
//! (ex-`locality_aware_steal`), fixed steal caps (ex-`steal_max`), the
//! immediate-buffer ablation, all three queue organizations, EPAQ
//! multi-queue, and block-level granularity — the refactored `Scheduler`
//! must produce **bit-identical** `RunStats` on fib / tree / nqueens
//! fixtures: same cycles, same steal/pop/push/iteration counts, same
//! result. The runs are deterministic, so equality here pins the whole
//! `(time, worker)` event order and the PRNG draw sequence, not just the
//! aggregate.

use gtap::compiler;
use gtap::coordinator::scheduler_ref::RefScheduler;
use gtap::coordinator::{
    Granularity, GtapConfig, PolicyConfig, RunStats, Scheduler, SchedulerKind, StealAmount,
    VictimSelect,
};
use gtap::ir::types::Value;
use gtap::sim::profile::Profiler;
use gtap::sim::{DeviceSpec, Memory};
use gtap::workloads::{fib, nqueens, tree};

/// Run one fixture through both schedulers; each gets its own fresh
/// memory, prepared identically by `make_args`.
fn stats_pair(
    cfg: &GtapConfig,
    src: &str,
    entry: &str,
    make_args: impl Fn(&mut Memory) -> Vec<Value>,
) -> (RunStats, RunStats) {
    let dev = DeviceSpec::h100();
    let module = compiler::compile(src, cfg.max_task_data_size).unwrap();
    let refactored = {
        let mut mem = Memory::new(module.globals_words());
        let args = make_args(&mut mem);
        let mut prof = Profiler::disabled();
        let mut s = Scheduler::new(&module, cfg, &dev).unwrap();
        s.spawn_root(entry, &args).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    let reference = {
        let mut mem = Memory::new(module.globals_words());
        let args = make_args(&mut mem);
        let mut prof = Profiler::disabled();
        let mut s = RefScheduler::new(&module, cfg, &dev).unwrap();
        s.spawn_root(entry, &args).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    (refactored, reference)
}

fn assert_equivalent(cfg: &GtapConfig, label: &str) {
    // fib: recursive spawns + taskwait joins
    let (a, b) = stats_pair(cfg, &fib::source(0, false), "fib", |_| {
        vec![Value::from_i64(13)]
    });
    assert_eq!(a, b, "fib diverged under {label}");
    assert_eq!(a.root_result.unwrap().as_i64(), 233);

    // synthetic full tree: payload arithmetic + accumulator memory
    let (a, b) = stats_pair(cfg, &tree::full_tree_source(4, 8), "tree", |mem| {
        let acc = mem.alloc(1);
        vec![Value::from_i64(7), Value::from_i64(7), Value(acc)]
    });
    assert_eq!(a, b, "tree diverged under {label}");

    // nqueens: spawn-only, no taskwait
    let mut nq_cfg = cfg.clone();
    nq_cfg.assume_no_taskwait = true;
    let (a, b) = stats_pair(&nq_cfg, &nqueens::source(3, false), "nqueens", |mem| {
        let acc = mem.alloc(1);
        vec![
            Value::from_i64(7),
            Value::from_i64(0),
            Value::from_i64(0),
            Value::from_i64(0),
            Value::from_i64(0),
            Value(acc),
        ]
    });
    assert_eq!(a, b, "nqueens diverged under {label}");
}

fn base_cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 8,
        block_size: 32,
        ..Default::default()
    }
}

#[test]
fn default_policy_reproduces_pre_refactor_scheduler() {
    assert_equivalent(&base_cfg(), "default policy");
}

#[test]
fn locality_first_matches_old_locality_aware_steal_flag() {
    let mut cfg = base_cfg();
    cfg.policy.victim_select = VictimSelect::LocalityFirst;
    assert_equivalent(&cfg, "locality-first victims");
}

#[test]
fn fixed_steal_caps_match_old_steal_max() {
    for max in [Some(1), Some(4), None] {
        let mut cfg = base_cfg();
        cfg.policy.steal_amount = StealAmount::Fixed { max };
        assert_equivalent(&cfg, &format!("steal cap {max:?}"));
    }
}

#[test]
fn immediate_buffer_ablation_still_matches() {
    let mut cfg = base_cfg();
    cfg.immediate_buffer = false;
    assert_equivalent(&cfg, "no immediate buffer");
}

#[test]
fn all_queue_organizations_match() {
    for kind in [
        SchedulerKind::WorkStealing,
        SchedulerKind::GlobalQueue,
        SchedulerKind::SequentialChaseLev,
    ] {
        let mut cfg = base_cfg();
        cfg.scheduler = kind;
        assert_equivalent(&cfg, &format!("{kind:?}"));
    }
}

#[test]
fn epaq_multi_queue_matches() {
    let cfg = GtapConfig {
        num_queues: 3,
        ..base_cfg()
    };
    let (a, b) = stats_pair(&cfg, &fib::source(2, true), "fib", |_| {
        vec![Value::from_i64(13)]
    });
    assert_eq!(a, b, "EPAQ fib diverged");
    assert_eq!(a.root_result.unwrap().as_i64(), 233);
}

#[test]
fn block_level_granularity_matches() {
    let cfg = GtapConfig {
        grid_size: 4,
        block_size: 64,
        granularity: Granularity::Block,
        ..Default::default()
    };
    let (a, b) = stats_pair(
        &cfg,
        &tree::full_tree_block_source(4, 8, 64),
        "tree",
        |mem| {
            let acc = mem.alloc(1);
            vec![Value::from_i64(4), Value::from_i64(7), Value(acc)]
        },
    );
    assert_eq!(a, b, "block-level tree diverged");
}

#[test]
fn combined_old_knobs_match() {
    // the strongest combination the monolith could express, all at once
    let mut cfg = base_cfg();
    cfg.policy = PolicyConfig {
        victim_select: VictimSelect::LocalityFirst,
        steal_amount: StealAmount::Fixed { max: Some(2) },
        ..Default::default()
    };
    cfg.immediate_buffer = false;
    cfg.num_queues = 2;
    assert_equivalent(&cfg, "locality + steal-cap + no-immediate + 2 queues");
}
