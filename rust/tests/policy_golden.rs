//! Golden equivalence: the policy-layer refactor must not change behavior.
//!
//! `coordinator::scheduler_ref::RefScheduler` is the pre-refactor
//! monolithic scheduler, kept verbatim. For every policy combination the
//! old monolith could express — the default, locality-aware stealing
//! (ex-`locality_aware_steal`), fixed steal caps (ex-`steal_max`), the
//! immediate-buffer ablation, all three queue organizations, EPAQ
//! multi-queue, and block-level granularity — the refactored `Scheduler`
//! must produce **bit-identical** `RunStats` on fib / tree / nqueens
//! fixtures: same cycles, same steal/pop/push/iteration counts, same
//! result. The runs are deterministic, so equality here pins the whole
//! `(time, worker)` event order and the PRNG draw sequence, not just the
//! aggregate.

use gtap::compiler;
use gtap::coordinator::scheduler_ref::RefScheduler;
use gtap::coordinator::{
    Granularity, GtapConfig, Placement, PolicyConfig, QueueSelect, RunStats, Scheduler,
    SchedulerKind, Session, SmTier, StealAmount, VictimSelect,
};
use gtap::ir::types::Value;
use gtap::ir::LoweredModule;
use gtap::sim::profile::Profiler;
use gtap::sim::{DeviceSpec, Memory};
use gtap::workloads::{fib, nqueens, tree};

/// Run one fixture through both schedulers; each gets its own fresh
/// memory, prepared identically by `make_args`.
fn stats_pair(
    cfg: &GtapConfig,
    src: &str,
    entry: &str,
    make_args: impl Fn(&mut Memory) -> Vec<Value>,
) -> (RunStats, RunStats) {
    let dev = DeviceSpec::h100();
    let module = compiler::compile(src, cfg.max_task_data_size).unwrap();
    let lowered = LoweredModule::lower(module, &dev);
    let module = &lowered.module;
    let refactored = {
        let mut mem = Memory::new(module.globals_words());
        let args = make_args(&mut mem);
        let mut prof = Profiler::disabled();
        let mut s = Scheduler::new(&lowered, cfg, &dev).unwrap();
        s.spawn_root(entry, &args).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    let reference = {
        let mut mem = Memory::new(module.globals_words());
        let args = make_args(&mut mem);
        let mut prof = Profiler::disabled();
        let mut s = RefScheduler::new(module, cfg, &dev).unwrap();
        s.spawn_root(entry, &args).unwrap();
        s.run(&mut mem, None, &mut prof).unwrap()
    };
    (refactored, reference)
}

fn assert_equivalent(cfg: &GtapConfig, label: &str) {
    // fib: recursive spawns + taskwait joins
    let (a, b) = stats_pair(cfg, &fib::source(0, false), "fib", |_| {
        vec![Value::from_i64(13)]
    });
    assert_eq!(a, b, "fib diverged under {label}");
    assert_eq!(a.root_result.unwrap().as_i64(), 233);

    // synthetic full tree: payload arithmetic + accumulator memory
    let (a, b) = stats_pair(cfg, &tree::full_tree_source(4, 8), "tree", |mem| {
        let acc = mem.alloc(1);
        vec![Value::from_i64(7), Value::from_i64(7), Value(acc)]
    });
    assert_eq!(a, b, "tree diverged under {label}");

    // nqueens: spawn-only, no taskwait
    let mut nq_cfg = cfg.clone();
    nq_cfg.assume_no_taskwait = true;
    let (a, b) = stats_pair(&nq_cfg, &nqueens::source(3, false), "nqueens", |mem| {
        let acc = mem.alloc(1);
        vec![
            Value::from_i64(7),
            Value::from_i64(0),
            Value::from_i64(0),
            Value::from_i64(0),
            Value::from_i64(0),
            Value(acc),
        ]
    });
    assert_eq!(a, b, "nqueens diverged under {label}");
}

fn base_cfg() -> GtapConfig {
    GtapConfig {
        grid_size: 8,
        block_size: 32,
        ..Default::default()
    }
}

#[test]
fn default_policy_reproduces_pre_refactor_scheduler() {
    assert_equivalent(&base_cfg(), "default policy");
}

#[test]
fn locality_first_matches_old_locality_aware_steal_flag() {
    let mut cfg = base_cfg();
    cfg.policy.victim_select = VictimSelect::LocalityFirst;
    assert_equivalent(&cfg, "locality-first victims");
}

#[test]
fn fixed_steal_caps_match_old_steal_max() {
    for max in [Some(1), Some(4), None] {
        let mut cfg = base_cfg();
        cfg.policy.steal_amount = StealAmount::Fixed { max };
        assert_equivalent(&cfg, &format!("steal cap {max:?}"));
    }
}

#[test]
fn immediate_buffer_ablation_still_matches() {
    let mut cfg = base_cfg();
    cfg.immediate_buffer = false;
    assert_equivalent(&cfg, "no immediate buffer");
}

#[test]
fn all_queue_organizations_match() {
    for kind in [
        SchedulerKind::WorkStealing,
        SchedulerKind::GlobalQueue,
        SchedulerKind::SequentialChaseLev,
    ] {
        let mut cfg = base_cfg();
        cfg.scheduler = kind;
        assert_equivalent(&cfg, &format!("{kind:?}"));
    }
}

#[test]
fn epaq_multi_queue_matches() {
    let cfg = GtapConfig {
        num_queues: 3,
        ..base_cfg()
    };
    let (a, b) = stats_pair(&cfg, &fib::source(2, true), "fib", |_| {
        vec![Value::from_i64(13)]
    });
    assert_eq!(a, b, "EPAQ fib diverged");
    assert_eq!(a.root_result.unwrap().as_i64(), 233);
}

#[test]
fn block_level_granularity_matches() {
    let cfg = GtapConfig {
        grid_size: 4,
        block_size: 64,
        granularity: Granularity::Block,
        ..Default::default()
    };
    let (a, b) = stats_pair(
        &cfg,
        &tree::full_tree_block_source(4, 8, 64),
        "tree",
        |mem| {
            let acc = mem.alloc(1);
            vec![Value::from_i64(4), Value::from_i64(7), Value(acc)]
        },
    );
    assert_eq!(a, b, "block-level tree diverged");
}

#[test]
fn combined_old_knobs_match() {
    // the strongest combination the monolith could express, all at once
    let mut cfg = base_cfg();
    cfg.policy = PolicyConfig {
        victim_select: VictimSelect::LocalityFirst,
        steal_amount: StealAmount::Fixed { max: Some(2) },
        ..Default::default()
    };
    cfg.immediate_buffer = false;
    cfg.num_queues = 2;
    assert_equivalent(&cfg, "locality + steal-cap + no-immediate + 2 queues");
}

// ---- golden pins for the PR-3 policy variants ---------------------------
//
// The pre-refactor monolith cannot express the priority pair, the adaptive
// steal controller or the per-SM tier, so their golden contract is pinned
// two ways: (1) hand-checkable *degenerate equivalences* — configurations
// where each new variant provably coincides with the default policy must
// reproduce the monolith bit-for-bit; (2) a hand-counted small-input
// `RunStats` pin for the active priority pair, plus activity pins showing
// each variant observably changes scheduling when it is supposed to.

#[test]
fn priority_pair_with_one_queue_matches_the_monolith() {
    // with a single queue every band clamps to 0: the priority pair is
    // exactly the default scheduler
    for pl in [Placement::PriorityDepth, Placement::PriorityUser] {
        let mut cfg = base_cfg();
        cfg.policy.queue_select = QueueSelect::Priority;
        cfg.policy.placement = pl;
        assert_equivalent(&cfg, &format!("priority pair ({}) over 1 queue", pl.name()));
    }
}

#[test]
fn adaptive_steal_without_victims_matches_the_monolith() {
    // a single worker never steals, so the adaptive controller never runs
    let mut cfg = base_cfg();
    cfg.grid_size = 1;
    cfg.policy.steal_amount = StealAmount::Adaptive;
    assert_equivalent(&cfg, "adaptive steal, single worker");
}

#[test]
fn sm_tier_without_traffic_matches_the_monolith() {
    // Spill with ample capacity never spills (the empty-pool check is
    // free), and Share never shares when every worker sits on its own SM
    // (grid 8 × 32 on a 132-SM H100): both reproduce the monolith exactly
    for tier in [SmTier::Spill, SmTier::Share] {
        let mut cfg = base_cfg();
        cfg.policy.sm_tier = tier;
        assert_equivalent(&cfg, &format!("sm-tier {} without traffic", tier.name()));
    }
}

#[test]
fn priority_pair_single_worker_hand_checked_counts() {
    // One worker, 8 bands, no immediate-execution buffer, spawn-only full
    // binary tree of depth 4 (walk(4) → 2^5 − 1 = 31 tasks, 30 spawns).
    // Hand-derived schedule: the root runs from the immediate buffer
    // (iteration 1, no pop); each depth band then drains in exactly one
    // probed pop (the priority scan starts at the lowest non-empty band)
    // and pushes its children as exactly one batch — iterations 2..=5 for
    // bands 1..=4, leaves spawn nothing, a single worker never steals and
    // the run quiesces with no idle iteration.
    let src = r#"
        #pragma gtap function
        void walk(int d) {
            if (d > 0) {
                #pragma gtap task
                walk(d - 1);
                #pragma gtap task
                walk(d - 1);
            }
        }
    "#;
    let mut cfg = GtapConfig {
        grid_size: 1,
        block_size: 32,
        num_queues: 8,
        assume_no_taskwait: true,
        immediate_buffer: false,
        ..Default::default()
    };
    cfg.policy.queue_select = QueueSelect::Priority;
    cfg.policy.placement = Placement::PriorityDepth;
    let mut s = Session::compile(src, cfg, DeviceSpec::h100()).unwrap();
    let stats = s.run("walk", &[Value::from_i64(4)]).unwrap();
    assert_eq!(stats.tasks_finished, 31);
    assert_eq!(stats.spawns, 30);
    assert_eq!(stats.iterations, 5);
    assert_eq!(stats.idle_iterations, 0);
    assert_eq!(stats.pops, 4, "one probed pop per depth band");
    assert_eq!(stats.pushes, 4, "one batched push per spawning band");
    assert_eq!(stats.steal_attempts, 0);
    assert_eq!(stats.steals_ok, 0);
    assert_eq!(stats.sm_spills, 0);
}

/// EPAQ fib(14) under the refactored scheduler with `mutate` applied —
/// the activity fixture for the drift pins below.
fn epaq_fib_stats(mutate: impl FnOnce(&mut GtapConfig)) -> RunStats {
    let mut cfg = GtapConfig {
        num_queues: 3,
        ..base_cfg()
    };
    mutate(&mut cfg);
    let dev = DeviceSpec::h100();
    let module = compiler::compile(&fib::source(2, true), cfg.max_task_data_size).unwrap();
    let lowered = LoweredModule::lower(module, &dev);
    let mut mem = Memory::new(lowered.module.globals_words());
    let mut prof = Profiler::disabled();
    let mut s = Scheduler::new(&lowered, &cfg, &dev).unwrap();
    s.spawn_root("fib", &[Value::from_i64(14)]).unwrap();
    let stats = s.run(&mut mem, None, &mut prof).unwrap();
    assert_eq!(stats.root_result.unwrap().as_i64(), 377);
    stats
}

#[test]
fn new_variants_are_observably_active_where_they_should_be() {
    let default = epaq_fib_stats(|_| {});
    // priority banding reroutes children away from the EPAQ classes
    let pri = epaq_fib_stats(|c| {
        c.policy.queue_select = QueueSelect::Priority;
        c.policy.placement = Placement::PriorityDepth;
    });
    assert_ne!(default, pri, "priority pair must change the schedule");
    // the adaptive controller must leave the pure-batch schedule once the
    // early steal failures push it into starved mode (whether it then
    // coincides with pure half depends on how the cumulative failure rate
    // evolves, so only the batch divergence is pinned — the regime switch
    // itself is unit-tested in policy::steal_amount)
    let adaptive = epaq_fib_stats(|c| c.policy.steal_amount = StealAmount::Adaptive);
    assert_ne!(default, adaptive, "adaptive must diverge from pure batch");
    // the share tier pools tasks once same-SM peers exist (4 warps/block)
    let share = epaq_fib_stats(|c| {
        c.block_size = 128;
        c.policy.sm_tier = SmTier::Share;
    });
    assert!(share.sm_spills > 0, "share tier must pool tasks: {share:?}");
}
