//! Figure 7: Full Binary Tree across problem sizes — depth, mem_ops and
//! compute_iters sweeps; block-level vs thread-level GTaP vs the CPU
//! comparator, normalized to the CPU (as in §6.3).
//!
//! Expected shape: GTaP increasingly ahead as size grows (paper: up to
//! 9.8× at D=22, 7.6× on the mem_ops sweep, 15.2× on compute_iters);
//! thread-level ahead of block-level at large D (ample slackness — paper
//! up to 4.6×), block-level competitive at small D.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::{full_scale, measure_curve};

fn sweep(name: &str, xs: &[i64], f: &(dyn Fn(&Exec, i64, u64) -> f64 + Sync)) {
    let g = grid(1000);
    let targets: Vec<(&str, Exec)> = vec![
        ("thread", Exec::gpu_thread(g, 64)),
        ("block", Exec::gpu_block(g, 64)),
        ("cpu72", Exec::cpu72()),
    ];
    let series: Vec<Series> = targets
        .iter()
        .map(|(label, exec)| Series {
            label: label.to_string(),
            points: measure_curve(xs, |&x, seed| f(&exec.clone().seed(seed), x, seed))
                .into_iter()
                .map(|(x, s)| (x as f64, s))
                .collect(),
        })
        .collect();
    println!("\n## fig7_{name} (seconds)\n");
    println!("{}", markdown_table(name, &series));
    println!("normalized to cpu72 (>1 = GTaP faster):");
    for (i, &x) in xs.iter().enumerate() {
        let cpu = series[2].points[i].1.median;
        println!(
            "  {x}: thread {:.2}x  block {:.2}x",
            cpu / series[0].points[i].1.median,
            cpu / series[1].points[i].1.median
        );
    }
    let p = write_csv(&format!("fig7_{name}"), &series).unwrap();
    println!("wrote {}", p.display());
}

fn main() {
    let (d_xs, mem_xs, comp_xs): (Vec<i64>, Vec<i64>, Vec<i64>) = if full_scale() {
        (
            vec![6, 8, 10, 12, 14, 16, 18],
            vec![0, 64, 256, 1024, 4096, 8192],
            vec![64, 256, 1024, 4096, 16384, 32768],
        )
    } else {
        (
            vec![6, 8, 10, 12, 14, 16],
            vec![0, 64, 256, 1024],
            vec![64, 256, 1024, 4096],
        )
    };
    // fixed "other two" as in §6.3: moderate mem + compute
    sweep("depth", &d_xs, &|e, d, _| {
        runners::run_full_tree(e, d, 128, 256, None).unwrap().seconds
    });
    sweep("mem_ops", &mem_xs, &|e, m, _| {
        runners::run_full_tree(e, 10, m, 256, None).unwrap().seconds
    });
    sweep("compute_iters", &comp_xs, &|e, c, _| {
        runners::run_full_tree(e, 10, 128, c, None).unwrap().seconds
    });
}
