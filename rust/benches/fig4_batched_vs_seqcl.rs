//! Figure 4: warp-cooperative batched pop/steal (Algorithm 1) vs
//! element-at-a-time Chase–Lev operations sequentialized within the warp,
//! sweeping the worker count on Fibonacci, N-Queens and Cilksort
//! (thread-level workers).
//!
//! Expected shape: batched wins almost everywhere; at very large P the
//! Chase–Lev baseline crosses over (its owner pops avoid the CAS on the
//! shared `count` word), yet the best time over the sweep stays with the
//! batched design.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::{full_scale, measure_curve};
use gtap::coordinator::SchedulerKind;

fn main() {
    let grids: Vec<usize> = if full_scale() {
        vec![1, 4, 16, 64, 256, 1024, 2048, 4096]
    } else {
        vec![1, 4, 16, 64, 256, 512]
    };
    let fib_n = if full_scale() { 26 } else { 22 };
    let nq_n = if full_scale() { 12 } else { 10 };
    let sort_n = if full_scale() { 1 << 18 } else { 1 << 14 };

    let benches: Vec<(&str, Box<dyn Fn(Exec) -> f64 + Sync>)> = vec![
        (
            "fib",
            Box::new(move |e: Exec| runners::run_fib(&e, fib_n, 0, false).unwrap().seconds),
        ),
        (
            "nqueens",
            Box::new(move |e: Exec| {
                runners::run_nqueens(&e.no_taskwait(), nq_n, 4, false)
                    .unwrap()
                    .seconds
            }),
        ),
        (
            "cilksort",
            Box::new(move |e: Exec| {
                runners::run_cilksort(&e, sort_n, 64, 256, false, 99)
                    .unwrap()
                    .seconds
            }),
        ),
    ];

    for (name, run) in &benches {
        let mut series = vec![];
        for (label, kind) in [
            ("batched", SchedulerKind::WorkStealing),
            ("seq-chaselev", SchedulerKind::SequentialChaseLev),
        ] {
            let points = measure_curve(&grids, |&g, seed| {
                run(Exec::gpu_thread(g, 32).scheduler(kind).seed(seed))
            })
            .into_iter()
            .map(|(g, s)| (g as f64, s))
            .collect();
            series.push(Series {
                label: label.to_string(),
                points,
            });
        }
        // the paper's summary claim: best-over-sweep is lower for batched
        let best = |s: &Series| {
            s.points
                .iter()
                .map(|(_, sm)| sm.median)
                .fold(f64::INFINITY, f64::min)
        };
        println!("\n## fig4_{name} (seconds; x = grid size)\n");
        println!("{}", markdown_table("grid", &series));
        println!(
            "best(batched) = {:.4e}  best(seq-chaselev) = {:.4e}  batched wins: {}",
            best(&series[0]),
            best(&series[1]),
            best(&series[0]) < best(&series[1]),
        );
        let p = write_csv(&format!("fig4_{name}"), &series).unwrap();
        println!("wrote {}", p.display());
    }
}
