//! Figure 6: per-warp timeline of mergesort — blue (task-function time,
//! intensity = active lanes) vs orange (queue ops / idle). Dumps the
//! timeline CSV for a subset of warps and prints the busy-fraction summary
//! that exposes the serial final merge (one warp busy, everyone else idle).

use gtap::bench::emit::write_text;
use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::full_scale;

fn main() {
    let n = if full_scale() { 1 << 18 } else { 1 << 14 };
    let exec = Exec::gpu_thread(64, 32).profiled();
    let out = runners::run_mergesort(&exec, n, 128, 42).unwrap();

    // subset of warps, like the figure
    let keep = 16u32;
    let mut csv = String::from("worker,start,busy,overhead,active_lanes,path_groups\n");
    for e in out.profiler.events.iter().filter(|e| e.worker < keep) {
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            e.worker, e.start, e.busy, e.overhead, e.active_lanes, e.path_groups
        ));
    }
    let p = write_text("fig6_timeline.csv", &csv).unwrap();
    println!("wrote {} ({} events total)", p.display(), out.profiler.events.len());

    println!("\nper-warp busy fraction (first {keep} warps):");
    for (w, busy, total) in out.profiler.utilization().iter().take(keep as usize) {
        println!(
            "  warp {w:3}: {:5.1}% busy ({busy} / {total} cycles)",
            100.0 * *busy as f64 / (*total).max(1) as f64
        );
    }
    // The tail of the run is the serial merge: find the last 10% of events
    // and count distinct busy workers — expect ~1.
    let t_end = out.profiler.events.iter().map(|e| e.start).max().unwrap_or(0);
    let cutoff = t_end - t_end / 10;
    let busy_tail: std::collections::BTreeSet<u32> = out
        .profiler
        .events
        .iter()
        .filter(|e| e.start >= cutoff && e.busy > 0)
        .map(|e| e.worker)
        .collect();
    println!(
        "\ndistinct busy warps in the final 10% of the run: {} (the serial merge tail)",
        busy_tail.len()
    );
}
