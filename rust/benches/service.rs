//! Service-engine bench: what the lower-once fix buys and what
//! multi-tenancy costs.
//!
//! * **Lowering amortization** — host-side cost of opening a session cold
//!   (compile + decode → superblock-fuse → trace-fuse) vs warm (content
//!   cache hit). The cold cost is what the pre-fix `Session::run_with`
//!   paid on *every* submission; the ratio is the per-run tax the service
//!   engine retires.
//! * **Single-tenant transparency** — a one-job service round must cost
//!   exactly the cycles of a one-shot `Session::run` (asserted, not just
//!   recorded).
//! * **Co-tenant interference** — a tenant's in-round completion stamp
//!   solo vs co-scheduled with a second tenant on the same fleet.
//! * **Replay digest** — an FNV-1a digest over the full outcome record of
//!   a fixed mixed schedule, run twice in-process (asserted equal) and
//!   written to the JSON; the CI service job re-runs the bench under
//!   `GTAP_BENCH_THREADS=1` and `=4` and diffs the digests, pinning that
//!   sweep threading never leaks into engine results.
//! * **Degraded-mode throughput** — the same schedule served under a
//!   fault plan sized off the measured solo makespan (a mid-round worker
//!   stall plus a run drain at 2/3 of the work span), with retry armed:
//!   what fraction of fault-free throughput the resilience layer retains,
//!   checkpointed retries vs from-the-root retries. Checkpointed runs are
//!   asserted to re-execute zero tasks and both degraded runs must end
//!   with every job Completed, results identical to the clean run.
//!
//! Results land in `BENCH_service.json` at the repo root (the CI
//! smoke-bench job records it with `GTAP_BENCH_SMOKE=1` and uploads the
//! artifact). Regenerate with `cargo bench --bench service`.

use gtap::bench::sweep::{self, full_scale, measure};
use gtap::coordinator::{FaultPlan, GtapConfig, Session};
use gtap::ir::types::Value;
use gtap::runtime::service::{
    AdmissionPolicy, JobOutcome, JobStatus, ResilienceConfig, ServiceEngine, SubmitOpts,
};
use gtap::sim::DeviceSpec;
use gtap::workloads::fib;
use std::path::PathBuf;
use std::time::Instant;

fn repo_root() -> PathBuf {
    // crate manifest dir is <repo>/rust; the workspace root is its parent
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

fn cfg(seed: u64) -> GtapConfig {
    GtapConfig {
        grid_size: 4,
        block_size: 32,
        seed,
        ..Default::default()
    }
}

/// FNV-1a over the `Debug` rendering of the outcome record — every field
/// of every `JobOutcome` (status, stamps, results, per-tenant and fleet
/// stats) feeds the digest, so any nondeterminism anywhere shows up.
fn digest(outs: &[JobOutcome]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in format!("{outs:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn main() {
    let smoke = std::env::var("GTAP_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let fib_n = if full_scale() {
        20
    } else if smoke {
        11
    } else {
        14
    };
    let jobs = if full_scale() { 8 } else { 4 };
    println!("service bench: fib({fib_n}), {jobs} jobs/tenant, grid 4 x block 32\n");
    let fib_src = fib::source(0, false);

    // ---- part 1: cold vs warm session opening ---------------------------
    // Cold = full compile + lower (the old per-run cost); warm = content
    // cache hit. Host nanos, medians over the seed sweep.
    let cold = measure(|seed| {
        let mut eng =
            ServiceEngine::new(cfg(seed), DeviceSpec::h100(), AdmissionPolicy::FairShare)
                .unwrap();
        let t = Instant::now();
        eng.open_session("cold", &fib_src).unwrap();
        t.elapsed().as_nanos() as f64
    });
    let warm = measure(|seed| {
        let mut eng =
            ServiceEngine::new(cfg(seed), DeviceSpec::h100(), AdmissionPolicy::FairShare)
                .unwrap();
        eng.open_session("first", &fib_src).unwrap();
        let t = Instant::now();
        eng.open_session("second", &fib_src).unwrap();
        t.elapsed().as_nanos() as f64
    });
    let speedup = cold.median / warm.median;
    println!(
        "  open_session cold {:.0} ns  warm {:.0} ns  ({speedup:.0}x — the per-run \
         relowering tax retired by lower-once)",
        cold.median, warm.median
    );
    assert!(
        warm.median < cold.median,
        "a cache hit must be cheaper than compile + lower \
         (warm {} ns >= cold {} ns)",
        warm.median,
        cold.median
    );

    // ---- part 2: single-tenant transparency -----------------------------
    let mut sess = Session::compile(&fib_src, cfg(sweep::SEED_BASE), DeviceSpec::h100())
        .unwrap();
    let session_run = sess.run("fib", &[Value::from_i64(fib_n)]).unwrap();
    let mut eng = ServiceEngine::new(
        cfg(sweep::SEED_BASE),
        DeviceSpec::h100(),
        AdmissionPolicy::FairShare,
    )
    .unwrap();
    let t = eng.open_session("solo", &fib_src).unwrap();
    eng.submit(t, "fib", &[Value::from_i64(fib_n)], SubmitOpts::default())
        .unwrap();
    eng.run_to_idle().unwrap();
    let solo_out = eng.take_outcomes().remove(0);
    assert_eq!(
        solo_out.fleet, session_run,
        "single-tenant round != Session::run"
    );
    let round_cycles = solo_out.fleet.cycles;
    let solo_completed_at = solo_out.stats.completed_at.expect("completed");
    println!(
        "  single-tenant round: {round_cycles} cycles, byte-identical to Session::run"
    );

    // ---- part 3: co-tenant interference ---------------------------------
    // The same fib job, alone vs sharing the fleet with a second tenant
    // running the same program: how much later does tenant 0 finish?
    let shared_completed = measure(|seed| {
        let mut eng =
            ServiceEngine::new(cfg(seed), DeviceSpec::h100(), AdmissionPolicy::FairShare)
                .unwrap();
        let a = eng.open_session("a", &fib_src).unwrap();
        let b = eng.open_session("b", &fib_src).unwrap();
        eng.submit(a, "fib", &[Value::from_i64(fib_n)], SubmitOpts::default())
            .unwrap();
        eng.submit(b, "fib", &[Value::from_i64(fib_n)], SubmitOpts::default())
            .unwrap();
        eng.run_to_idle().unwrap();
        let outs = eng.take_outcomes();
        let o = outs.iter().find(|o| o.tenant == a).unwrap();
        assert_eq!(o.status, JobStatus::Completed);
        o.stats.completed_at.expect("completed") as f64
    });
    let interference = shared_completed.median / solo_completed_at as f64;
    println!(
        "  co-tenant interference: solo completes at {solo_completed_at} cy, \
         shared median {:.0} cy ({interference:.2}x)",
        shared_completed.median
    );

    // ---- part 4: replay digest ------------------------------------------
    let schedule = || -> Vec<JobOutcome> {
        let mut eng = ServiceEngine::new(
            cfg(sweep::SEED_BASE),
            DeviceSpec::h100(),
            AdmissionPolicy::FairShare,
        )
        .unwrap();
        let a = eng.open_session("a", &fib_src).unwrap();
        let b = eng.open_session("b", &fib_src).unwrap();
        for j in 0..jobs {
            eng.submit(
                a,
                "fib",
                &[Value::from_i64(fib_n - (j % 3) as i64)],
                SubmitOpts::default(),
            )
            .unwrap();
            eng.submit(
                b,
                "fib",
                &[Value::from_i64(fib_n - 1)],
                SubmitOpts {
                    priority: (j % 2) as u8,
                    ..Default::default()
                },
            )
            .unwrap();
        }
        eng.run_to_idle().unwrap();
        eng.take_outcomes()
    };
    let outs = schedule();
    let d1 = digest(&outs);
    let d2 = digest(&schedule());
    assert_eq!(d1, d2, "replaying the schedule changed the outcome digest");
    assert!(outs
        .iter()
        .all(|o| o.status == JobStatus::Completed && o.result.is_some()));
    println!(
        "  replay digest over {} outcomes: {d1:#018x} (stable across reruns)",
        outs.len()
    );

    // ---- part 5: degraded-mode throughput -------------------------------
    // The part-4 schedule served with retry armed, three ways: fault-free
    // (the clean reference), and under a fault plan derived from the
    // measured solo makespan — a worker stall a third of the way into the
    // work span plus a run drain at two thirds — with checkpointed and
    // from-the-root retries. The engine escalates the drain deadline per
    // drained round, so both degraded runs terminate with every job
    // Completed and results identical to the clean run; the metric is how
    // much virtual service time degradation costs.
    let startup = DeviceSpec::h100().startup;
    let work = round_cycles - startup;
    let fault_spec = format!(
        "stall@{}:w1:2000;deadline@{}",
        startup + work / 3,
        startup + (work * 2) / 3
    );
    let run_resilient = |faults: Option<&str>, checkpoint: bool| {
        let mut c = cfg(sweep::SEED_BASE);
        if let Some(f) = faults {
            c.faults = FaultPlan::parse(f).unwrap();
        }
        let mut eng =
            ServiceEngine::new(c, DeviceSpec::h100(), AdmissionPolicy::FairShare).unwrap();
        eng.set_resilience(ResilienceConfig {
            retry: true,
            max_retries: 16,
            retry_budget: 256,
            backoff_base: 1 << 8,
            checkpoint,
            ..Default::default()
        });
        let a = eng.open_session("a", &fib_src).unwrap();
        let b = eng.open_session("b", &fib_src).unwrap();
        for j in 0..jobs {
            eng.submit(
                a,
                "fib",
                &[Value::from_i64(fib_n - (j % 3) as i64)],
                SubmitOpts::default(),
            )
            .unwrap();
            eng.submit(
                b,
                "fib",
                &[Value::from_i64(fib_n - 1)],
                SubmitOpts {
                    priority: (j % 2) as u8,
                    ..Default::default()
                },
            )
            .unwrap();
        }
        eng.run_to_idle().unwrap();
        let mut outs = eng.take_outcomes();
        outs.sort_by_key(|o| o.job);
        assert!(outs.iter().all(|o| o.status == JobStatus::Completed));
        let results: Vec<_> = outs.iter().map(|o| (o.job, o.tenant, o.result)).collect();
        let retries = eng.accounting(a).jobs_retried + eng.accounting(b).jobs_retried;
        let reexec =
            eng.accounting(a).tasks_reexecuted + eng.accounting(b).tasks_reexecuted;
        (eng.virtual_cycles(), eng.rounds(), retries, reexec, results)
    };
    let (clean_cycles, clean_rounds, _, _, clean_results) = run_resilient(None, true);
    let (ck_cycles, ck_rounds, ck_retries, ck_reexec, ck_results) =
        run_resilient(Some(&fault_spec), true);
    let (nc_cycles, nc_rounds, nc_retries, nc_reexec, nc_results) =
        run_resilient(Some(&fault_spec), false);
    assert_eq!(ck_results, clean_results, "degraded results diverged (checkpoint)");
    assert_eq!(nc_results, clean_results, "degraded results diverged (from-root)");
    assert_eq!(ck_reexec, 0, "checkpointed retries must re-execute nothing");
    let retained_ck = clean_cycles as f64 / ck_cycles as f64;
    let retained_nc = clean_cycles as f64 / nc_cycles as f64;
    println!(
        "  degraded mode under {fault_spec}:\n    clean      {clean_cycles} cy, \
         {clean_rounds} round(s)\n    checkpoint {ck_cycles} cy, {ck_rounds} round(s), \
         {ck_retries} retrie(s), 0 reexecuted ({:.0}% throughput retained)\n    \
         from-root  {nc_cycles} cy, {nc_rounds} round(s), {nc_retries} retrie(s), \
         {nc_reexec} reexecuted ({:.0}% throughput retained)",
        retained_ck * 100.0,
        retained_nc * 100.0,
    );

    // ---- machine-readable record: BENCH_service.json --------------------
    let json = format!(
        "{{\n  \"bench\": \"service\",\n  \"measured\": true,\n  \
         \"command\": \"cargo bench --bench service\",\n  \
         \"runs\": {},\n  \"smoke\": {},\n  \
         \"sizes\": {{\"fib_n\": {fib_n}, \"jobs_per_tenant\": {jobs}, \
         \"grid\": 4, \"block\": 32}},\n  \
         \"lowering\": {{\"cold_open_ns_median\": {:.1}, \
         \"warm_open_ns_median\": {:.1}, \"lower_once_speedup\": {speedup:.1}}},\n  \
         \"single_tenant\": {{\"round_cycles\": {round_cycles}, \
         \"matches_session_run\": true}},\n  \
         \"interference\": {{\"solo_completed_at\": {solo_completed_at}, \
         \"shared_completed_at_median\": {:.1}, \"ratio\": {interference:.3}}},\n  \
         \"resilience\": {{\"fault_spec\": \"{fault_spec}\", \
         \"clean_cycles\": {clean_cycles}, \"clean_rounds\": {clean_rounds}, \
         \"degraded_cycles_checkpoint\": {ck_cycles}, \
         \"degraded_cycles_from_root\": {nc_cycles}, \
         \"throughput_retained_checkpoint\": {retained_ck:.3}, \
         \"throughput_retained_from_root\": {retained_nc:.3}, \
         \"retries_checkpoint\": {ck_retries}, \"retries_from_root\": {nc_retries}, \
         \"tasks_reexecuted_checkpoint\": 0, \
         \"tasks_reexecuted_from_root\": {nc_reexec}}},\n  \
         \"replay_digest\": \"{d1:#018x}\"\n}}\n",
        sweep::runs(),
        smoke,
        cold.median,
        warm.median,
        shared_completed.median,
    );
    let path = repo_root().join("BENCH_service.json");
    std::fs::write(&path, json).expect("write BENCH_service.json");
    println!("\nwrote {}", path.display());
}
