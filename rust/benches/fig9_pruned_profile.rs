//! Figure 9: profiling the pruned B-ary tree with thread-level workers —
//! intra-warp utilization collapses as the tree thins (warps see far fewer
//! than 32 ready tasks), which is why block-level workers win Fig. 8's
//! large-work sweeps. Paper setting: D=32, mem_ops=256, compute_iters=8192
//! (scaled here; GTAP_BENCH_FULL=1 restores it).

use gtap::bench::emit::write_text;
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::full_scale;

fn main() {
    let (d, mem, comp) = if full_scale() {
        (32, 256, 8192)
    } else {
        (16, 128, 1024)
    };
    let exec = Exec::gpu_thread(grid(1000), 64).profiled();
    let out = runners::run_pruned_tree(&exec, d, mem, comp, 5).unwrap();

    println!(
        "pruned tree D={d} mem_ops={mem} compute_iters={comp}: {} tasks, {:.3e} s",
        out.stats.tasks_finished, out.seconds
    );
    println!(
        "mean active lanes per busy warp iteration: {:.2} / 32",
        out.profiler.mean_active_lanes()
    );
    let qs = out
        .profiler
        .busy_time_percentiles(&[0.1, 0.5, 0.9, 0.99]);
    println!(
        "busy-iteration cycles p10/p50/p90/p99: {:.0} / {:.0} / {:.0} / {:.0}",
        qs[0], qs[1], qs[2], qs[3]
    );

    // lane-occupancy histogram — the quantitative core of Fig. 9
    let mut histo = [0u64; 33];
    for e in &out.profiler.events {
        if e.active_lanes > 0 {
            histo[e.active_lanes as usize] += 1;
        }
    }
    let mut csv = String::from("active_lanes,iterations\n");
    println!("\nactive-lane histogram (busy iterations):");
    for (lanes, count) in histo.iter().enumerate() {
        if *count > 0 {
            println!("  {lanes:2} lanes: {count}");
        }
        csv.push_str(&format!("{lanes},{count}\n"));
    }
    let p = write_text("fig9_lane_histogram.csv", &csv).unwrap();
    println!("wrote {}", p.display());

    // compare against the full tree at similar size: utilization should be
    // much higher there
    let full = runners::run_full_tree(&Exec::gpu_thread(grid(1000), 64).profiled(), 12, mem, comp, None)
        .unwrap();
    println!(
        "\nfull-binary-tree comparison: mean active lanes {:.2} / 32 (pruned: {:.2})",
        full.profiler.mean_active_lanes(),
        out.profiler.mean_active_lanes()
    );
}
