//! Figure 8: Depth-Dependent Pruned B-ary Tree (B=3, p(d)=1−d/D) across
//! problem sizes — the irregular, thinning workload where block-level
//! workers overtake thread-level at large per-task work (paper: up to 2.2×
//! on the mem_ops sweep and 4.3× on compute_iters) because warps see far
//! fewer than 32 ready tasks (Fig. 9).

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::{full_scale, measure_curve};

fn sweep(name: &str, xs: &[i64], f: &(dyn Fn(&Exec, i64, i64) -> f64 + Sync)) {
    let g = grid(1000);
    let targets: Vec<(&str, Exec)> = vec![
        ("thread", Exec::gpu_thread(g, 64)),
        ("block", Exec::gpu_block(g, 64)),
        ("cpu72", Exec::cpu72()),
    ];
    let series: Vec<Series> = targets
        .iter()
        .map(|(label, exec)| Series {
            label: label.to_string(),
            points: measure_curve(xs, |&x, seed| {
                f(&exec.clone().seed(seed), x, seed as i64)
            })
            .into_iter()
            .map(|(x, s)| (x as f64, s))
            .collect(),
        })
        .collect();
    println!("\n## fig8_{name} (seconds)\n");
    println!("{}", markdown_table(name, &series));
    println!("block/thread time ratio (<1 = block faster):");
    for (i, &x) in xs.iter().enumerate() {
        println!(
            "  {x}: {:.2}",
            series[1].points[i].1.median / series[0].points[i].1.median
        );
    }
    let p = write_csv(&format!("fig8_{name}"), &series).unwrap();
    println!("wrote {}", p.display());
}

fn main() {
    let (d_xs, mem_xs, comp_xs): (Vec<i64>, Vec<i64>, Vec<i64>) = if full_scale() {
        (
            vec![8, 12, 16, 20, 24, 32],
            vec![0, 64, 256, 1024, 4096, 8192],
            vec![64, 256, 1024, 4096, 16384],
        )
    } else {
        (
            vec![8, 12, 16],
            vec![0, 128, 512],
            vec![128, 512, 2048],
        )
    };
    sweep("depth", &d_xs, &|e, d, seed| {
        runners::run_pruned_tree(e, d, 128, 256, seed).unwrap().seconds
    });
    sweep("mem_ops", &mem_xs, &|e, m, seed| {
        runners::run_pruned_tree(e, 14, m, 256, seed).unwrap().seconds
    });
    sweep("compute_iters", &comp_xs, &|e, c, seed| {
        runners::run_pruned_tree(e, 14, 128, c, seed).unwrap().seconds
    });
}
