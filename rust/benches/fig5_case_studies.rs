//! Figure 5: execution time across problem sizes for the four case studies
//! (Fibonacci, N-Queens, Mergesort, Cilksort) — GTaP on the GPU model vs
//! the 72-core CPU task runtime vs single-worker CPU (top: absolute,
//! bottom: normalized to GTaP).
//!
//! Expected shapes (§6.2): fib — GTaP loses small n, overtakes around
//! n≈28-equivalent; nqueens — GTaP increasingly ahead (paper: 14.6× at
//! n=16); mergesort — GTaP *much* slower at scale (serial merge tail;
//! paper: 103× at 10⁷); cilksort — GTaP modestly ahead (memory bound).
//! Sizes are scaled per DESIGN.md §8.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::{full_scale, measure_curve};

fn three_way(
    name: &str,
    xs: &[i64],
    gtap: &(dyn Fn(i64, u64) -> f64 + Sync),
    cpu: &(dyn Fn(i64, u64) -> f64 + Sync),
    seq: &(dyn Fn(i64, u64) -> f64 + Sync),
) {
    let mk = |label: &str, f: &(dyn Fn(i64, u64) -> f64 + Sync)| Series {
        label: label.to_string(),
        points: measure_curve(xs, |&x, seed| f(x, seed))
            .into_iter()
            .map(|(x, s)| (x as f64, s))
            .collect(),
    };
    let series = vec![mk("GTaP(gpu)", gtap), mk("OpenMP(cpu72)", cpu), mk("CPU-seq", seq)];
    println!("\n## fig5_{name} (seconds; x = problem size)\n");
    println!("{}", markdown_table("size", &series));
    // normalized-to-GTaP rows (the bottom half of Fig. 5)
    println!("normalized to GTaP (>1 = GTaP faster):");
    for (i, &x) in xs.iter().enumerate() {
        let g = series[0].points[i].1.median;
        println!(
            "  {x}: cpu72 {:.2}x  seq {:.2}x",
            series[1].points[i].1.median / g,
            series[2].points[i].1.median / g
        );
    }
    let p = write_csv(&format!("fig5_{name}"), &series).unwrap();
    println!("wrote {}", p.display());
}

fn main() {
    // Fibonacci: no cutoff — a task per call (Table 3: 4000x32 thread)
    let fib_ns: Vec<i64> = if full_scale() {
        vec![16, 20, 24, 26, 28, 30]
    } else {
        vec![16, 20, 22, 24]
    };
    let g = grid(4000);
    three_way(
        "fibonacci",
        &fib_ns,
        &|n, seed| {
            runners::run_fib(&Exec::gpu_thread(g, 32).seed(seed), n, 0, false)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_fib(&Exec::cpu72().seed(seed), n, 0, false)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_fib(&Exec::cpu_seq().seed(seed), n, 0, false)
                .unwrap()
                .seconds
        },
    );

    // N-Queens: cutoff depth 7 scaled to min(n-2, 7); ASSUME_NO_TASKWAIT
    let nq_ns: Vec<i64> = if full_scale() {
        vec![8, 9, 10, 11, 12, 13]
    } else {
        vec![8, 9, 10, 11]
    };
    let g = grid(2000);
    let depth_for = |n: i64| 7.min(n - 2).max(1);
    three_way(
        "nqueens",
        &nq_ns,
        &|n, seed| {
            runners::run_nqueens(
                &Exec::gpu_thread(g, 32).no_taskwait().seed(seed),
                n,
                depth_for(n),
                false,
            )
            .unwrap()
            .seconds
        },
        &|n, seed| {
            runners::run_nqueens(&Exec::cpu72().no_taskwait().seed(seed), n, depth_for(n), false)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_nqueens(&Exec::cpu_seq().no_taskwait().seed(seed), n, depth_for(n), false)
                .unwrap()
                .seconds
        },
    );

    // Mergesort: cutoffs 128 (GTaP) / 4096 (OpenMP), as in §6.2
    let ms_ns: Vec<i64> = if full_scale() {
        vec![1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22]
    } else {
        vec![1 << 10, 1 << 12, 1 << 14, 1 << 16]
    };
    let g = grid(1000);
    three_way(
        "mergesort",
        &ms_ns,
        &|n, seed| {
            runners::run_mergesort(&Exec::gpu_thread(g, 32).seed(seed), n as usize, 128, seed)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_mergesort(&Exec::cpu72().seed(seed), n as usize, 4096, seed)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_mergesort(&Exec::cpu_seq().seed(seed), n as usize, 4096, seed)
                .unwrap()
                .seconds
        },
    );

    // Cilksort: Table 3 cutoffs (GTaP 64/256; OpenMP 4096/4096)
    let g = grid(2000);
    three_way(
        "cilksort",
        &ms_ns,
        &|n, seed| {
            runners::run_cilksort(&Exec::gpu_thread(g, 32).seed(seed), n as usize, 64, 256, false, seed)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_cilksort(&Exec::cpu72().seed(seed), n as usize, 4096, 4096, false, seed)
                .unwrap()
                .seconds
        },
        &|n, seed| {
            runners::run_cilksort(&Exec::cpu_seq().seed(seed), n as usize, 4096, 4096, false, seed)
                .unwrap()
                .seconds
        },
    );
}
