//! Table 3: the per-benchmark evaluation settings, echoed and smoke-run.
//! Each configuration is validated by executing its benchmark at a small
//! size under exactly the Table-3 granularity/flags (grid scaled in quick
//! mode; GTAP_BENCH_FULL=1 uses the paper's worker counts).

use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::{grid, TABLE3};

fn main() {
    println!("| Benchmark | Grid Size | Block Size | Granularity | flags |");
    println!("|---|---|---|---|---|");
    for s in TABLE3 {
        println!(
            "| {} | {} | {} | {} | {} |",
            s.name,
            s.grid_size,
            s.block_size,
            s.granularity,
            if s.assume_no_taskwait {
                "-DGTAP_ASSUME_NO_TASKWAIT"
            } else {
                ""
            }
        );
    }
    println!("\nsmoke-running each setting (scaled grids in quick mode):\n");

    let fib = runners::run_fib(&Exec::gpu_thread(grid(4000), 32), 18, 0, false).unwrap();
    println!("Fibonacci      ok: {:.3e} s, {} tasks", fib.seconds, fib.stats.tasks_finished);

    let nq = runners::run_nqueens(
        &Exec::gpu_thread(grid(2000), 32).no_taskwait(),
        9,
        4,
        false,
    )
    .unwrap();
    println!("N-Queens       ok: {:.3e} s, {} tasks", nq.seconds, nq.stats.tasks_finished);

    let ms = runners::run_mergesort(&Exec::gpu_thread(grid(1000), 32), 1 << 13, 128, 1).unwrap();
    println!("Mergesort      ok: {:.3e} s, {} tasks", ms.seconds, ms.stats.tasks_finished);

    let cs = runners::run_cilksort(&Exec::gpu_thread(grid(2000), 32), 1 << 13, 64, 256, false, 1)
        .unwrap();
    println!("Cilksort       ok: {:.3e} s, {} tasks", cs.seconds, cs.stats.tasks_finished);

    let tt = runners::run_full_tree(&Exec::gpu_thread(grid(1000), 64), 8, 64, 128, None).unwrap();
    let tb = runners::run_full_tree(&Exec::gpu_block(grid(1000), 64), 8, 64, 128, None).unwrap();
    println!(
        "SyntheticTree  ok: thread {:.3e} s / block {:.3e} s, {} tasks",
        tt.seconds, tb.seconds, tt.stats.tasks_finished
    );
}
