//! Figure 11: Fibonacci profiling with and without EPAQ (paper: n=40,
//! cutoff=10; scaled here). EPAQ cuts the tail of per-warp task-function
//! time per persistent-kernel loop by separating serial cutoff tasks,
//! pre-join recursion and post-join continuations into different queues —
//! fewer control paths per warp, less intra-warp serialization.

use gtap::bench::emit::write_text;
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::{full_scale, measure};

fn main() {
    // paper setting: n=40, cutoff=10, 4000x32 warps (n scaled in quick mode)
    let n = if full_scale() { 40 } else { 36 };
    let cutoff = 10;
    let g = 4000;
    let _ = grid(0); // (grid() reserved for the other figures)

    let mut report = String::new();
    for (label, epaq, queues) in [("1-queue", false, 1usize), ("epaq", true, 3)] {
        let exec = Exec::gpu_thread(g, 32).queues(queues).profiled();
        let out = runners::run_fib(&exec, n, cutoff, epaq).unwrap();
        let qs = out
            .profiler
            .busy_time_percentiles(&[0.5, 0.9, 0.99, 1.0]);
        let groups: f64 = {
            let busy: Vec<_> = out.profiler.events.iter().filter(|e| e.busy > 0).collect();
            busy.iter().map(|e| e.path_groups as f64).sum::<f64>() / busy.len().max(1) as f64
        };
        println!(
            "{label:8}: {:.4e} s | busy-cycles p50 {:.0}  p90 {:.0}  p99 {:.0}  max {:.0} | \
             mean path groups/warp {groups:.2}",
            out.seconds, qs[0], qs[1], qs[2], qs[3]
        );
        report.push_str(&format!(
            "{label},{},{},{},{},{},{groups}\n",
            out.seconds, qs[0], qs[1], qs[2], qs[3]
        ));
        // per-warp busy-time distribution CSV (the bottom-right histogram)
        let mut csv = String::from("busy_cycles\n");
        for e in out.profiler.events.iter().filter(|e| e.busy > 0) {
            csv.push_str(&format!("{}\n", e.busy));
        }
        let p = write_text(&format!("fig11_busytime_{label}.csv"), &csv).unwrap();
        println!("          wrote {}", p.display());
    }
    write_text(
        "fig11_summary.csv",
        &format!("label,seconds,p50,p90,p99,max,path_groups\n{report}"),
    )
    .unwrap();

    // headline claim: EPAQ speedup on fib
    let t1 = measure(|seed| {
        runners::run_fib(&Exec::gpu_thread(g, 32).queues(1).seed(seed), n, cutoff, false)
            .unwrap()
            .seconds
    });
    let te = measure(|seed| {
        runners::run_fib(&Exec::gpu_thread(g, 32).queues(3).seed(seed), n, cutoff, true)
            .unwrap()
            .seconds
    });
    println!(
        "\nEPAQ speedup on fib(n={n}, cutoff={cutoff}): {:.2}x (paper: up to 1.8x)",
        t1.median / te.median
    );
}
