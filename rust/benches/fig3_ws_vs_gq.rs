//! Figure 3: work stealing vs the global-queue approach, sweeping the
//! worker count (grid size) at fixed block sizes (32 and 256).
//!
//! (a) block-level workers on Full Binary Tree (compute-heavy and
//!     memory-heavy variants); (b) thread-level workers on Fibonacci,
//!     N-Queens and Cilksort. Expected shape: work stealing ~1/P then
//!     saturation; global queue flat-lines early from contention on the
//!     shared queue words.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::{full_scale, measure_curve};
use gtap::coordinator::SchedulerKind;

fn grids() -> Vec<usize> {
    if full_scale() {
        vec![1, 4, 16, 64, 256, 1024, 4096]
    } else {
        vec![1, 4, 16, 64, 256]
    }
}

fn sweep(
    label: &str,
    kind: SchedulerKind,
    block: usize,
    run: &(dyn Fn(Exec) -> f64 + Sync),
    mk: &(dyn Fn(usize, usize) -> Exec + Sync),
) -> Series {
    // every (grid point, repetition) pair runs as an independent work item
    // across threads; output is byte-identical to the serial nested loops
    let points = measure_curve(&grids(), |&g, seed| {
        run(mk(g, block).scheduler(kind).seed(seed))
    })
    .into_iter()
    .map(|(g, s)| (g as f64, s))
    .collect();
    Series {
        label: format!("{label}/b{block}"),
        points,
    }
}

fn main() {
    let mut all: Vec<(String, Vec<Series>)> = vec![];

    // (a) block-level: Full Binary Tree, compute-heavy & memory-heavy
    let depth = if full_scale() { 12 } else { 9 };
    for (variant, mem, comp) in [("compute", 0i64, 2048i64), ("memory", 512, 0)] {
        let mut series = vec![];
        for block in [32usize, 256] {
            for (label, kind) in [
                ("ws", SchedulerKind::WorkStealing),
                ("gq", SchedulerKind::GlobalQueue),
            ] {
                series.push(sweep(
                    label,
                    kind,
                    block,
                    &|e| {
                        runners::run_full_tree(&e, depth, mem / e.cfg.block_size as i64 * e.cfg.block_size as i64, comp, None)
                            .unwrap()
                            .seconds
                    },
                    &Exec::gpu_block,
                ));
            }
        }
        all.push((format!("fig3a_fbt_{variant}"), series));
    }

    // (b) thread-level: Fibonacci, N-Queens, Cilksort
    let fib_n = if full_scale() { 26 } else { 22 };
    let nq_n = if full_scale() { 12 } else { 10 };
    let sort_n = if full_scale() { 1 << 18 } else { 1 << 14 };
    for (name, run) in [
        (
            "fib",
            Box::new(move |e: Exec| runners::run_fib(&e, fib_n, 0, false).unwrap().seconds)
                as Box<dyn Fn(Exec) -> f64 + Sync>,
        ),
        (
            "nqueens",
            Box::new(move |e: Exec| {
                runners::run_nqueens(&e.no_taskwait(), nq_n, 4, false)
                    .unwrap()
                    .seconds
            }),
        ),
        (
            "cilksort",
            Box::new(move |e: Exec| {
                runners::run_cilksort(&e, sort_n, 64, 256, false, 99)
                    .unwrap()
                    .seconds
            }),
        ),
    ] {
        let mut series = vec![];
        for block in [32usize, 256] {
            for (label, kind) in [
                ("ws", SchedulerKind::WorkStealing),
                ("gq", SchedulerKind::GlobalQueue),
            ] {
                series.push(sweep(label, kind, block, run.as_ref(), &Exec::gpu_thread));
            }
        }
        all.push((format!("fig3b_{name}"), series));
    }

    for (name, series) in &all {
        println!("\n## {name} (seconds, median [IQR]; x = grid size)\n");
        println!("{}", markdown_table("grid", series));
        let p = write_csv(name, series).expect("write csv");
        println!("wrote {}", p.display());
    }
}
