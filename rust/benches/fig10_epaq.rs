//! Figure 10: effect of EPAQ across cutoff depths — normalized execution
//! time relative to the 1-queue configuration (EPAQ disabled).
//!
//! Fibonacci uses three queues (non-cutoff / cutoff-serial / post-taskwait
//! continuation), N-Queens two (non-cutoff vs cutoff rows), Cilksort three
//! (non-cutoff / serial-sort / serial-merge). Expected shape (§6.4): ~1.8×
//! speedup on Fibonacci, no significant difference on N-Queens/Cilksort.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::settings::grid;
use gtap::bench::sweep::{full_scale, measure_curve};

fn compare(
    name: &str,
    queues: usize,
    xs: &[i64],
    run: &(dyn Fn(&Exec, i64, bool, u64) -> f64 + Sync),
) {
    let g = grid(2000);
    let mk = |label: &str, epaq: bool, nq: usize| Series {
        label: label.to_string(),
        points: measure_curve(xs, |&x, seed| {
            run(&Exec::gpu_thread(g, 32).queues(nq).seed(seed), x, epaq, seed)
        })
        .into_iter()
        .map(|(x, s)| (x as f64, s))
        .collect(),
    };
    let series = vec![mk("1-queue", false, 1), mk("epaq", true, queues)];
    println!("\n## fig10_{name} (seconds; x = cutoff)\n");
    println!("{}", markdown_table("cutoff", &series));
    println!("normalized time epaq / 1-queue (<1 = EPAQ faster):");
    for (i, &x) in xs.iter().enumerate() {
        println!(
            "  cutoff {x}: {:.3}",
            series[1].points[i].1.median / series[0].points[i].1.median
        );
    }
    let p = write_csv(&format!("fig10_{name}"), &series).unwrap();
    println!("wrote {}", p.display());
}

fn main() {
    // EPAQ's fib benefit needs deep oversubscription (the paper's n=40 /
    // 4000x32 warps, Table 3): batches then genuinely mix serial-cutoff,
    // recursive and continuation path classes. We keep the paper's grid
    // and scale n in quick mode (DESIGN.md §8).
    let fib_n = if full_scale() { 40 } else { 36 };
    let fib_cutoffs: Vec<i64> = if full_scale() {
        vec![6, 8, 10, 12, 14]
    } else {
        vec![8, 10, 12, 14]
    };
    {
        let g = 4000;
        let mk = |label: &str, epaq: bool, nq: usize| Series {
            label: label.to_string(),
            points: measure_curve(&fib_cutoffs, |&x, seed| {
                runners::run_fib(
                    &Exec::gpu_thread(g, 32).queues(nq).seed(seed),
                    fib_n,
                    x,
                    epaq,
                )
                .unwrap()
                .seconds
            })
            .into_iter()
            .map(|(x, s)| (x as f64, s))
            .collect(),
        };
        let series = vec![mk("1-queue", false, 1), mk("epaq", true, 3)];
        println!("\n## fig10_fibonacci (seconds; x = cutoff; n={fib_n}, grid={g})\n");
        println!("{}", markdown_table("cutoff", &series));
        println!("normalized time epaq / 1-queue (<1 = EPAQ faster):");
        for (i, &x) in fib_cutoffs.iter().enumerate() {
            println!(
                "  cutoff {x}: {:.3}",
                series[1].points[i].1.median / series[0].points[i].1.median
            );
        }
        let p = write_csv("fig10_fibonacci", &series).unwrap();
        println!("wrote {}", p.display());
    }

    let nq_n = if full_scale() { 13 } else { 11 };
    let nq_cutoffs: Vec<i64> = vec![3, 4, 5, 6];
    compare("nqueens", 2, &nq_cutoffs, &|e, depth, epaq, _| {
        runners::run_nqueens(&e.clone().no_taskwait(), nq_n, depth, epaq)
            .unwrap()
            .seconds
    });

    let sort_n: usize = if full_scale() { 1 << 18 } else { 1 << 14 };
    let sort_cutoffs: Vec<i64> = vec![32, 64, 128, 256];
    compare("cilksort", 3, &sort_cutoffs, &|e, cutoff, epaq, seed| {
        runners::run_cilksort(e, sort_n, cutoff, cutoff * 4, epaq, seed)
            .unwrap()
            .seconds
    });
}
