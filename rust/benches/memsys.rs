//! Memory-system model bench: flat vs modeled cost pipelines on real
//! workloads, plus the synthetic coalescing microbench.
//!
//! Part 1 — **flat vs modeled end to end**: fib (thread-level,
//! record-heavy), the synthetic tree (payload arithmetic) and BFS
//! (block-level, CSR-walking — the workload the model exists for) run
//! under both `--memsys` modes; the table reports simulated medians and
//! the modeled runs' transaction/hit-rate counters.
//!
//! Part 2 — **coalesced vs scattered synthetic streams**: identical
//! per-lane access counts through `sim::memsys::MemSys` directly, packed
//! into shared 128B lines vs spread one line per lane. The bench *fails*
//! if the scattered stream is not strictly more expensive — the same
//! invariant `rust/tests/memsys_model.rs` property-tests, re-checked here
//! on the recorded numbers.
//!
//! Results land in `BENCH_memsys.json` at the repo root (the CI
//! smoke-bench job records it with `GTAP_BENCH_SMOKE=1` and uploads the
//! artifact). Regenerate with `cargo bench --bench memsys`.

use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::{self, full_scale, measure};
use gtap::sim::divergence::LanePath;
use gtap::sim::memsys::{coalesce, AccessKind, MemAccess, MemSys, MemSysMode, MemSysStats};
use gtap::sim::DeviceSpec;
use std::path::PathBuf;

fn repo_root() -> PathBuf {
    // crate manifest dir is <repo>/rust; the workspace root is its parent
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

/// One workload's flat/modeled medians plus the modeled counters.
struct Row {
    name: &'static str,
    flat_median_s: f64,
    modeled_median_s: f64,
    stats: MemSysStats,
}

fn pct(hits: u64, misses: u64) -> f64 {
    let t = hits + misses;
    if t == 0 {
        0.0
    } else {
        100.0 * hits as f64 / t as f64
    }
}

fn main() {
    let smoke = std::env::var("GTAP_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let fib_n = if full_scale() {
        26
    } else if smoke {
        17
    } else {
        22
    };
    let tree_d = if full_scale() {
        14
    } else if smoke {
        8
    } else {
        11
    };
    let bfs_n = if full_scale() {
        40_000
    } else if smoke {
        1_500
    } else {
        8_000
    };
    let grid = if smoke { 32 } else { 128 };
    println!("memsys bench: fib({fib_n}) / tree({tree_d}) / bfs({bfs_n}), grid {grid}\n");

    type Runner = Box<dyn Fn(MemSysMode, u64) -> (f64, MemSysStats) + Sync>;
    let workloads: Vec<(&'static str, Runner)> = vec![
        (
            "fib",
            Box::new(move |m, seed| {
                let out = runners::run_fib(
                    &Exec::gpu_thread(grid, 32).seed(seed).memsys(m),
                    fib_n,
                    0,
                    false,
                )
                .unwrap();
                (out.seconds, out.stats.memsys)
            }),
        ),
        (
            "tree",
            Box::new(move |m, seed| {
                let out = runners::run_full_tree(
                    &Exec::gpu_thread(grid, 32).seed(seed).memsys(m),
                    tree_d,
                    16,
                    64,
                    None,
                )
                .unwrap();
                (out.seconds, out.stats.memsys)
            }),
        ),
        (
            "bfs",
            Box::new(move |m, seed| {
                let out = runners::run_bfs(
                    &Exec::gpu_block(grid, 64).no_taskwait().seed(seed).memsys(m),
                    bfs_n,
                    4,
                    seed,
                )
                .unwrap();
                (out.seconds, out.stats.memsys)
            }),
        ),
    ];

    let mut rows: Vec<Row> = vec![];
    for (name, run) in &workloads {
        let flat = measure(|seed| run(MemSysMode::Flat, seed).0);
        // capture the base-seed run's counters from inside the measured
        // sweep instead of re-simulating the workload afterwards
        let stats_cell: std::sync::Mutex<Option<MemSysStats>> = std::sync::Mutex::new(None);
        let modeled = measure(|seed| {
            let (seconds, stats) = run(MemSysMode::Modeled, seed);
            if seed == sweep::SEED_BASE {
                *stats_cell.lock().unwrap() = Some(stats);
            }
            seconds
        });
        let stats = stats_cell
            .into_inner()
            .unwrap()
            .expect("the base seed is always part of the sweep");
        println!(
            "  {name:6} flat {:.4e} s  modeled {:.4e} s  ({:+.1}%)  \
             [{} tx, L1 {:.1}%, L2 {:.1}%, {} bank conflicts]",
            flat.median,
            modeled.median,
            100.0 * (modeled.median - flat.median) / flat.median,
            stats.transactions,
            pct(stats.l1_hits, stats.l1_misses),
            pct(stats.l2_hits, stats.l2_misses),
            stats.smem_bank_conflicts,
        );
        rows.push(Row {
            name,
            flat_median_s: flat.median,
            modeled_median_s: modeled.median,
            stats,
        });
    }

    // ---- part 2: synthetic coalesced vs scattered streams ---------------
    let dev = DeviceSpec::h100();
    let positions = 64u64;
    let lanes: Vec<LanePath> = (0..32).map(|_| LanePath { hash: 1, cycles: 0 }).collect();
    let synthetic = |scattered: bool| -> (u64, u64) {
        let streams: Vec<Vec<MemAccess>> = (0..32u64)
            .map(|lane| {
                (0..positions)
                    .map(|p| {
                        let addr = if scattered {
                            (p * 33 + lane) * coalesce::LINE_WORDS
                        } else {
                            p * coalesce::LINE_WORDS + lane % coalesce::LINE_WORDS
                        };
                        MemAccess {
                            addr,
                            kind: AccessKind::GlobalLoad,
                        }
                    })
                    .collect()
            })
            .collect();
        let mut m = MemSys::modeled(&dev);
        let mut stats = MemSysStats::default();
        let cycles = m.charge_warp(0, &lanes, |i| &streams[i][..], &dev, &mut stats);
        (cycles, stats.transactions)
    };
    let (coalesced_cycles, coalesced_tx) = synthetic(false);
    let (scattered_cycles, scattered_tx) = synthetic(true);
    println!(
        "\n  synthetic 32-lane x {positions}-deep stream: \
         coalesced {coalesced_cycles} cy ({coalesced_tx} tx), \
         scattered {scattered_cycles} cy ({scattered_tx} tx), \
         {:.1}x",
        scattered_cycles as f64 / coalesced_cycles as f64
    );
    assert!(
        scattered_cycles > coalesced_cycles,
        "coalescing invariant violated: scattered {scattered_cycles} <= \
         coalesced {coalesced_cycles}"
    );

    // ---- machine-readable record: BENCH_memsys.json ---------------------
    let row_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    \"{}\": {{\"flat_median_s\": {:.6e}, \"modeled_median_s\": {:.6e}, \
                 \"modeled_over_flat\": {:.3}, \"transactions\": {}, \"sectors\": {}, \
                 \"l1_hit_pct\": {:.2}, \"l2_hit_pct\": {:.2}, \"smem_bank_conflicts\": {}}}",
                r.name,
                r.flat_median_s,
                r.modeled_median_s,
                r.modeled_median_s / r.flat_median_s,
                r.stats.transactions,
                r.stats.sectors,
                pct(r.stats.l1_hits, r.stats.l1_misses),
                pct(r.stats.l2_hits, r.stats.l2_misses),
                r.stats.smem_bank_conflicts,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"memsys\",\n  \"measured\": true,\n  \
         \"command\": \"cargo bench --bench memsys\",\n  \
         \"runs\": {},\n  \"smoke\": {},\n  \
         \"sizes\": {{\"fib_n\": {fib_n}, \"tree_depth\": {tree_d}, \"bfs_n\": {bfs_n}, \
         \"grid\": {grid}}},\n  \
         \"workloads\": {{\n{}\n  }},\n  \
         \"synthetic\": {{\"lanes\": 32, \"positions\": {positions}, \
         \"coalesced_cycles\": {coalesced_cycles}, \"scattered_cycles\": {scattered_cycles}, \
         \"coalesced_transactions\": {coalesced_tx}, \
         \"scattered_transactions\": {scattered_tx}, \
         \"scattered_over_coalesced\": {:.3}}}\n}}\n",
        sweep::runs(),
        smoke,
        row_json.join(",\n"),
        scattered_cycles as f64 / coalesced_cycles as f64,
    );
    let path = repo_root().join("BENCH_memsys.json");
    std::fs::write(&path, json).expect("write BENCH_memsys.json");
    println!("\nwrote {}", path.display());
}
