//! Hot-path microbenchmark: four interpreter tiers on identical segment
//! streams —
//!
//! * **ref** — the pre-refactor module-walking baseline
//!   (`sim::interp_ref`), re-resolving per-function vectors per segment;
//! * **decoded** — flattened per-instruction dispatch (`sim::interp` over
//!   `ir::decoded`, the PR-1 engine);
//! * **fused** — superblock block-at-a-time dispatch (`Interp::fused` over
//!   `ir::superblock`): folded per-block cycle charges, task-data masks,
//!   macro-op streams;
//! * **traced** — trace-fused dispatch (`Interp::traced` over
//!   `ir::traced`, the production engine): multi-block traces across
//!   biased branches, block-local register demotion into a fixed scratch
//!   array, and an inline cache keyed on the last-executed trace.
//!
//! The measured corpus is the segment populations of the paper's
//! workloads: **fib** (recursive first segments, continuations, leaves in
//! tree proportions), the synthetic **tree** task (spawns + `payload`
//! intrinsic + atomic accumulate), and **nqueens** (irregular spawn-in-loop
//! segments + the serial-leaf intrinsic). All tiers execute identical
//! streams; the bench asserts their simulated cycle totals agree before
//! timing anything, so a speedup can never come from computing less.
//!
//! Results (median wall-clock over `GTAP_BENCH_RUNS` reps, plus an
//! end-to-end scheduler run) are printed and recorded in
//! `BENCH_hotpath.json` at the repo root — the repo's running perf
//! baseline. Regenerate with `cargo bench --bench hotpath`.
//!
//! **Regression guard:** with `GTAP_BENCH_ENFORCE=1` (set by the CI
//! smoke-bench job) the bench *fails* unless, on the fib and tree streams,
//! `traced` is ≥ 1.6× faster than `decoded`, `fused` is ≥ 1.3× faster
//! than `decoded`, and `decoded` stays ≥ 2.0× faster than `ref`.

use gtap::bench::sweep;
use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, TaskId, NO_TASK};
use gtap::coordinator::{GtapConfig, Session};
use gtap::ir::bytecode::Module;
use gtap::ir::decoded::DecodedModule;
use gtap::ir::superblock::FusedModule;
use gtap::ir::traced::TracedModule;
use gtap::ir::types::Value;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use gtap::util::prng::mix64;
use gtap::util::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// Segments per timed repetition (≥ 10k warm segments by a wide margin).
const SEGMENTS: usize = 200_000;

/// Acceptance bars enforced under `GTAP_BENCH_ENFORCE=1` (fib + tree).
const MIN_DECODED_OVER_REF: f64 = 2.0;
const MIN_FUSED_OVER_DECODED: f64 = 1.3;
const MIN_TRACED_OVER_DECODED: f64 = 1.6;

const FIB_SRC: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

/// The fib segment stream: `(state, n)` pairs approximating the segment
/// population of a fib(30) run — every task runs a first segment (state 0,
/// recursion or leaf) and every recursive task a continuation (state 1).
fn fib_stream() -> Vec<(u16, i64)> {
    let pattern: &[(u16, i64)] = &[
        (0, 30),
        (0, 17),
        (0, 1),
        (1, 9),
        (0, 0),
        (0, 25),
        (1, 30),
        (0, 2),
        (1, 4),
        (0, 12),
    ];
    (0..SEGMENTS).map(|i| pattern[i % pattern.len()]).collect()
}

/// The tree segment stream: `(state, depth)` over the synthetic tree task.
fn tree_stream() -> Vec<(u16, i64)> {
    let pattern: &[(u16, i64)] = &[(0, 8), (0, 0), (1, 5), (0, 3), (0, 0), (1, 1)];
    (0..SEGMENTS).map(|i| pattern[i % pattern.len()]).collect()
}

/// The nqueens segment stream: `(state, row)` on a 12-board with cutoff 7.
/// Rows mix interior spawn loops, cutoff rows (serial-leaf intrinsic) and
/// full-board leaves; nqueens is spawn-only, so every segment is state 0.
fn nqueens_stream() -> Vec<(u16, i64)> {
    let pattern: &[(u16, i64)] = &[(0, 0), (0, 12), (0, 7), (0, 11), (0, 3), (0, 12), (0, 5)];
    // a quarter of the fib/tree length: cutoff rows run the serial solver
    (0..SEGMENTS / 4).map(|i| pattern[i % pattern.len()]).collect()
}

/// Which workload a fixture primes task data for.
#[derive(Clone, Copy, PartialEq)]
enum Kind {
    Fib,
    Tree,
    Nqueens,
}

struct SegmentFixture {
    module: Module,
    decoded: DecodedModule,
    fused: FusedModule,
    traced: TracedModule,
    dev: DeviceSpec,
    records: RecordPool,
    mem: Memory,
    task: TaskId,
    kind: Kind,
    /// Accumulator pointer for workloads that take one (word address).
    acc: u64,
}

impl SegmentFixture {
    fn new(src: &str, func: &str, kind: Kind) -> SegmentFixture {
        let module = compile_default(src).expect("bench source compiles");
        let decoded = DecodedModule::decode(&module);
        let dev = DeviceSpec::h100();
        let fused = FusedModule::fuse(&decoded, &dev);
        // static trace formation, exactly as the production scheduler builds it
        let traced = TracedModule::build(&decoded, &fused, &dev, None);
        let fid = module.func_id(func).expect("entry exists");
        assert_eq!(fid, 0, "fixture assumes the entry is function 0");
        let words = module
            .funcs
            .iter()
            .map(|f| f.layout.words())
            .max()
            .unwrap()
            .max(1);
        let mut records = RecordPool::new(64, words, 8);
        let mut mem = Memory::new(module.globals_words());
        let acc = if kind == Kind::Fib { 0 } else { mem.alloc(1) };
        let task = records.alloc(fid, NO_TASK).unwrap();
        SegmentFixture {
            module,
            decoded,
            fused,
            traced,
            dev,
            records,
            mem,
            task,
            kind,
            acc,
        }
    }

    /// Fib needs the child slots populated for state-1 `ChildResult` reads.
    fn attach_children(&mut self) {
        let off = self.module.funcs[0]
            .layout
            .result_offset()
            .expect("fib returns int") as usize;
        for v in [1u64, 0] {
            let child = self.records.alloc(0, self.task).unwrap();
            self.records.push_child(self.task, child).unwrap();
            self.records.data_mut(child)[off] = v;
            self.records.meta_mut(child).done = true;
        }
        // keep children attached across segments: the bench only re-reads
        self.records.meta_mut(self.task).pending_children = 0;
    }

    /// Run the stream through one interpreter tier; returns (seconds,
    /// simulated-cycle checksum).
    fn time_tier(&mut self, tier: Tier, stream: &[(u16, i64)]) -> (f64, u64) {
        match tier {
            Tier::Ref => self.time_ref(stream),
            Tier::Decoded | Tier::Fused | Tier::Traced => self.time_interp(stream, tier),
        }
    }

    fn time_interp(&mut self, stream: &[(u16, i64)], tier: Tier) -> (f64, u64) {
        let interp = match tier {
            Tier::Fused => Interp::fused(&self.decoded, &self.fused, &self.dev, 1, false),
            Tier::Traced => Interp::traced(&self.decoded, &self.traced, &self.dev, 1, false),
            _ => Interp::new(&self.decoded, &self.dev, 1, false),
        };
        let mut frame = LaneFrame::sized(&self.decoded);
        let mut log = Vec::new();
        let mut checksum = 0u64;
        let t = Instant::now();
        for (i, &(state, n)) in stream.iter().enumerate() {
            prime(&mut self.records, self.task, self.kind, self.acc, n, i as u64);
            frame.reset(&self.decoded, self.task, 0, state, 0);
            match interp.run(&mut frame, &mut self.mem, &mut self.records, &mut log) {
                StepResult::Done(o) => checksum = checksum.wrapping_add(o.cycles),
                other => panic!("unexpected {other:?}"),
            }
        }
        (t.elapsed().as_secs_f64(), checksum)
    }

    /// Same stream through the module-walking reference interpreter.
    fn time_ref(&mut self, stream: &[(u16, i64)]) -> (f64, u64) {
        let interp = RefInterp {
            module: &self.module,
            dev: &self.dev,
            block_width: 1,
            xla_payload: false,
            record_accesses: false,
        };
        let mut frame = RefLaneFrame::new();
        let mut log = Vec::new();
        let mut checksum = 0u64;
        let t = Instant::now();
        for (i, &(state, n)) in stream.iter().enumerate() {
            prime(&mut self.records, self.task, self.kind, self.acc, n, i as u64);
            frame.reset(&self.module, self.task, 0, state, 0);
            match interp.run(&mut frame, &mut self.mem, &mut self.records, &mut log) {
                StepResult::Done(o) => checksum = checksum.wrapping_add(o.cycles),
                other => panic!("unexpected {other:?}"),
            }
        }
        (t.elapsed().as_secs_f64(), checksum)
    }
}

/// Prime the fixture task's record for the next segment. A free function
/// over the fixture's *fields* so the borrow of `records` stays disjoint
/// from the module/device borrows the interpreter holds.
fn prime(records: &mut RecordPool, task: TaskId, kind: Kind, acc: u64, v: i64, i: u64) {
    let data = records.data_mut(task);
    match kind {
        Kind::Fib => {
            data[0] = v as u64;
            data[1] = i;
        }
        Kind::Tree => {
            // tree(depth, seed, acc)
            data[0] = v as u64;
            data[1] = i;
            data[2] = acc;
        }
        Kind::Nqueens => {
            // nqueens(n, row, left, down, right, acc) on a 12-board
            let m = mix64(i);
            data[0] = 12;
            data[1] = v as u64;
            data[2] = m & 0xFFF;
            data[3] = (m >> 12) & 0xFFF;
            data[4] = (m >> 24) & 0xFFF;
            data[5] = acc;
        }
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Tier {
    Ref,
    Decoded,
    Fused,
    Traced,
}

struct Comparison {
    name: &'static str,
    ref_median_s: f64,
    decoded_median_s: f64,
    fused_median_s: f64,
    traced_median_s: f64,
    decoded_over_ref: f64,
    fused_over_decoded: f64,
    traced_over_decoded: f64,
}

fn compare(
    name: &'static str,
    fixture: &mut SegmentFixture,
    stream: &[(u16, i64)],
    reps: usize,
) -> Comparison {
    // correctness gate: identical simulated cycles before any timing
    let (_, c_ref) = fixture.time_tier(Tier::Ref, stream);
    let (_, c_dec) = fixture.time_tier(Tier::Decoded, stream);
    let (_, c_fus) = fixture.time_tier(Tier::Fused, stream);
    let (_, c_trc) = fixture.time_tier(Tier::Traced, stream);
    assert_eq!(
        c_ref, c_dec,
        "{name}: decoded and reference interpreters disagree on simulated cycles"
    );
    assert_eq!(
        c_dec, c_fus,
        "{name}: fused and decoded interpreters disagree on simulated cycles"
    );
    assert_eq!(
        c_dec, c_trc,
        "{name}: traced and decoded interpreters disagree on simulated cycles"
    );
    // interleave reps so thermal/frequency drift hits all tiers equally
    let mut ref_s = Vec::with_capacity(reps);
    let mut dec_s = Vec::with_capacity(reps);
    let mut fus_s = Vec::with_capacity(reps);
    let mut trc_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        ref_s.push(fixture.time_tier(Tier::Ref, stream).0);
        dec_s.push(fixture.time_tier(Tier::Decoded, stream).0);
        fus_s.push(fixture.time_tier(Tier::Fused, stream).0);
        trc_s.push(fixture.time_tier(Tier::Traced, stream).0);
    }
    let r = Summary::of(&ref_s).median;
    let d = Summary::of(&dec_s).median;
    let f = Summary::of(&fus_s).median;
    let t = Summary::of(&trc_s).median;
    Comparison {
        name,
        ref_median_s: r,
        decoded_median_s: d,
        fused_median_s: f,
        traced_median_s: t,
        decoded_over_ref: r / d,
        fused_over_decoded: d / f,
        traced_over_decoded: d / t,
    }
}

/// End-to-end scheduler run (the production trace-fused engine): fib(24)
/// on 256 warps.
fn end_to_end_fib(reps: usize) -> f64 {
    let samples: Vec<f64> = (0..reps)
        .map(|i| {
            let cfg = GtapConfig {
                grid_size: 256,
                block_size: 32,
                seed: 0xBE5E_ED00 + i as u64,
                ..Default::default()
            };
            let mut s = Session::compile(FIB_SRC, cfg, DeviceSpec::h100()).unwrap();
            let t = Instant::now();
            let stats = s.run("fib", &[Value::from_i64(24)]).unwrap();
            assert_eq!(stats.root_result.unwrap().as_i64(), 46368);
            t.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&samples).median
}

fn repo_root() -> PathBuf {
    // crate manifest dir is <repo>/rust; the workspace root is its parent
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

fn json_entry(c: &Comparison) -> String {
    format!(
        "{{\"ref_median_s\": {:.6e}, \"decoded_median_s\": {:.6e}, \
         \"fused_median_s\": {:.6e}, \"traced_median_s\": {:.6e}, \
         \"decoded_over_ref\": {:.3}, \"fused_over_decoded\": {:.3}, \
         \"traced_over_decoded\": {:.3}}}",
        c.ref_median_s,
        c.decoded_median_s,
        c.fused_median_s,
        c.traced_median_s,
        c.decoded_over_ref,
        c.fused_over_decoded,
        c.traced_over_decoded,
    )
}

fn main() {
    let reps = sweep::runs();
    let enforce = std::env::var("GTAP_BENCH_ENFORCE").map(|v| v == "1").unwrap_or(false);
    println!("hotpath microbench: {SEGMENTS} segments/rep, {reps} reps, 4 tiers\n");

    let mut fib = SegmentFixture::new(FIB_SRC, "fib", Kind::Fib);
    fib.attach_children();
    let fib_cmp = compare("fib_segments", &mut fib, &fib_stream(), reps);

    // tree is void: its continuation reads no child results, so no child
    // records need attaching
    let tree_src = gtap::workloads::tree::full_tree_source(16, 64);
    let mut tree = SegmentFixture::new(&tree_src, "tree", Kind::Tree);
    let tree_cmp = compare("tree_segments", &mut tree, &tree_stream(), reps);

    let nq_src = gtap::workloads::nqueens::source(7, true);
    let mut nq = SegmentFixture::new(&nq_src, "nqueens", Kind::Nqueens);
    let nq_cmp = compare("nqueens_segments", &mut nq, &nqueens_stream(), reps);

    let e2e = end_to_end_fib(reps);

    for c in [&fib_cmp, &tree_cmp, &nq_cmp] {
        println!(
            "{:16} ref {:.4e} s  decoded {:.4e} s  fused {:.4e} s  traced {:.4e} s  \
             (decoded/ref {:.2}x, fused/decoded {:.2}x, traced/decoded {:.2}x)",
            c.name,
            c.ref_median_s,
            c.decoded_median_s,
            c.fused_median_s,
            c.traced_median_s,
            c.decoded_over_ref,
            c.fused_over_decoded,
            c.traced_over_decoded,
        );
    }
    println!("fib(24) end-to-end (traced scheduler): {e2e:.4e} s median");

    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"measured\": true,\n  \
         \"command\": \"cargo bench --bench hotpath\",\n  \
         \"segments_per_rep\": {SEGMENTS},\n  \"runs\": {reps},\n  \
         \"thresholds\": {{\"decoded_over_ref_min\": {MIN_DECODED_OVER_REF}, \
         \"fused_over_decoded_min\": {MIN_FUSED_OVER_DECODED}, \
         \"traced_over_decoded_min\": {MIN_TRACED_OVER_DECODED}, \
         \"enforced\": {enforce}}},\n  \
         \"results\": {{\n    \
         \"fib_segments\": {},\n    \
         \"tree_segments\": {},\n    \
         \"nqueens_segments\": {},\n    \
         \"fib24_end_to_end\": {{\"scheduler_median_s\": {:.6e}}}\n  }}\n}}\n",
        json_entry(&fib_cmp),
        json_entry(&tree_cmp),
        json_entry(&nq_cmp),
        e2e,
    );
    let path = repo_root().join("BENCH_hotpath.json");
    std::fs::write(&path, json).expect("write BENCH_hotpath.json");
    println!("\nwrote {}", path.display());

    if enforce {
        for c in [&fib_cmp, &tree_cmp] {
            assert!(
                c.decoded_over_ref >= MIN_DECODED_OVER_REF,
                "{}: decoded over ref regressed to {:.2}x (min {MIN_DECODED_OVER_REF}x)",
                c.name,
                c.decoded_over_ref
            );
            assert!(
                c.fused_over_decoded >= MIN_FUSED_OVER_DECODED,
                "{}: fused over decoded is {:.2}x (min {MIN_FUSED_OVER_DECODED}x)",
                c.name,
                c.fused_over_decoded
            );
            assert!(
                c.traced_over_decoded >= MIN_TRACED_OVER_DECODED,
                "{}: traced over decoded is {:.2}x (min {MIN_TRACED_OVER_DECODED}x)",
                c.name,
                c.traced_over_decoded
            );
        }
        println!("regression guard: all thresholds met");
    }
}
