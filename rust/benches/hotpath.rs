//! Hot-path microbenchmark: decoded flattened dispatch (`sim::interp`)
//! vs the pre-refactor module-walking baseline (`sim::interp_ref`), on the
//! two segment mixes the paper's workloads are made of:
//!
//! * **fib segments** — the fib(30) state machine's segment population:
//!   recursive first segments (branch + two spawns + join), post-join
//!   continuations and base-case leaves, in tree proportions;
//! * **tree segments** — the synthetic full-binary-tree task function
//!   (spawns + `payload` intrinsic + atomic accumulate).
//!
//! Both interpreters execute identical segment streams; the bench asserts
//! their simulated cycle totals agree before timing anything, so a speedup
//! can never come from computing less.
//!
//! Results (median wall-clock over `GTAP_BENCH_RUNS` reps, plus an
//! end-to-end scheduler run) are printed and recorded in
//! `BENCH_hotpath.json` at the repo root — the repo's running perf
//! baseline. Regenerate with `cargo bench --bench hotpath`.

use gtap::bench::sweep;
use gtap::compiler::compile_default;
use gtap::coordinator::records::{RecordPool, TaskId, NO_TASK};
use gtap::coordinator::{GtapConfig, Session};
use gtap::ir::bytecode::Module;
use gtap::ir::decoded::DecodedModule;
use gtap::ir::types::Value;
use gtap::sim::interp_ref::{RefInterp, RefLaneFrame};
use gtap::sim::{DeviceSpec, Interp, LaneFrame, Memory, StepResult};
use gtap::util::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// Segments per timed repetition (≥ 10k warm segments by a wide margin).
const SEGMENTS: usize = 200_000;

const FIB_SRC: &str = r#"
    #pragma gtap function
    int fib(int n) {
        if (n < 2) return n;
        int a; int b;
        #pragma gtap task
        a = fib(n - 1);
        #pragma gtap task
        b = fib(n - 2);
        #pragma gtap taskwait
        return a + b;
    }
"#;

/// The fib segment stream: `(state, n)` pairs approximating the segment
/// population of a fib(30) run — every task runs a first segment (state 0,
/// recursion or leaf) and every recursive task a continuation (state 1).
fn fib_stream() -> Vec<(u16, i64)> {
    let pattern: &[(u16, i64)] = &[
        (0, 30),
        (0, 17),
        (0, 1),
        (1, 9),
        (0, 0),
        (0, 25),
        (1, 30),
        (0, 2),
        (1, 4),
        (0, 12),
    ];
    (0..SEGMENTS).map(|i| pattern[i % pattern.len()]).collect()
}

/// The tree segment stream: `(state, depth)` over the synthetic tree task.
fn tree_stream() -> Vec<(u16, i64)> {
    let pattern: &[(u16, i64)] = &[(0, 8), (0, 0), (1, 5), (0, 3), (0, 0), (1, 1)];
    (0..SEGMENTS).map(|i| pattern[i % pattern.len()]).collect()
}

struct SegmentFixture {
    module: Module,
    decoded: DecodedModule,
    dev: DeviceSpec,
    records: RecordPool,
    mem: Memory,
    task: TaskId,
    /// Extra task-data words set per reset: (offset, value) template.
    extra_args: Vec<(usize, u64)>,
}

impl SegmentFixture {
    fn new(src: &str, func: &str, extra_alloc_words: u64) -> SegmentFixture {
        let module = compile_default(src).expect("bench source compiles");
        let decoded = DecodedModule::decode(&module);
        let fid = module.func_id(func).expect("entry exists");
        assert_eq!(fid, 0, "fixture assumes the entry is function 0");
        let words = module
            .funcs
            .iter()
            .map(|f| f.layout.words())
            .max()
            .unwrap()
            .max(1);
        let mut records = RecordPool::new(64, words, 8);
        let mut mem = Memory::new(module.globals_words());
        let mut extra_args = Vec::new();
        if extra_alloc_words > 0 {
            let addr = mem.alloc(extra_alloc_words);
            // tree(depth, seed, acc): acc pointer is arg slot 2
            extra_args.push((2usize, addr));
        }
        let task = records.alloc(fid, NO_TASK).unwrap();
        SegmentFixture {
            module,
            decoded,
            dev: DeviceSpec::h100(),
            records,
            mem,
            task,
            extra_args,
        }
    }

    /// Fib needs the child slots populated for state-1 `ChildResult` reads.
    fn attach_children(&mut self) {
        let off = self.module.funcs[0]
            .layout
            .result_offset()
            .expect("fib returns int") as usize;
        for v in [1u64, 0] {
            let child = self.records.alloc(0, self.task).unwrap();
            self.records.push_child(self.task, child).unwrap();
            self.records.data_mut(child)[off] = v;
            self.records.meta_mut(child).done = true;
        }
        // keep children attached across segments: the bench only re-reads
        self.records.meta_mut(self.task).pending_children = 0;
    }

    fn prime(&mut self, arg0: u64, seed: u64) {
        let data = self.records.data_mut(self.task);
        data[0] = arg0;
        if data.len() > 1 {
            data[1] = seed;
        }
        for &(slot, v) in &self.extra_args {
            self.records.data_mut(self.task)[slot] = v;
        }
    }

    /// Run the stream through the decoded interpreter; returns (seconds,
    /// simulated-cycle checksum).
    fn time_decoded(&mut self, stream: &[(u16, i64)]) -> (f64, u64) {
        let interp = Interp::new(&self.decoded, &self.dev, 1, false);
        let mut frame = LaneFrame::sized(&self.decoded);
        let mut log = Vec::new();
        let mut checksum = 0u64;
        let t = Instant::now();
        for (i, &(state, n)) in stream.iter().enumerate() {
            self.prime(n as u64, i as u64);
            frame.reset(&self.decoded, self.task, 0, state, 0);
            match interp.run(&mut frame, &mut self.mem, &mut self.records, &mut log) {
                StepResult::Done(o) => checksum = checksum.wrapping_add(o.cycles),
                other => panic!("unexpected {other:?}"),
            }
        }
        (t.elapsed().as_secs_f64(), checksum)
    }

    /// Same stream through the module-walking reference interpreter.
    fn time_ref(&mut self, stream: &[(u16, i64)]) -> (f64, u64) {
        let interp = RefInterp {
            module: &self.module,
            dev: &self.dev,
            block_width: 1,
            xla_payload: false,
        };
        let mut frame = RefLaneFrame::new();
        let mut log = Vec::new();
        let mut checksum = 0u64;
        let t = Instant::now();
        for (i, &(state, n)) in stream.iter().enumerate() {
            self.prime(n as u64, i as u64);
            frame.reset(&self.module, self.task, 0, state, 0);
            match interp.run(&mut frame, &mut self.mem, &mut self.records, &mut log) {
                StepResult::Done(o) => checksum = checksum.wrapping_add(o.cycles),
                other => panic!("unexpected {other:?}"),
            }
        }
        (t.elapsed().as_secs_f64(), checksum)
    }
}

struct Comparison {
    name: &'static str,
    ref_median_s: f64,
    decoded_median_s: f64,
    speedup: f64,
}

fn compare(
    name: &'static str,
    fixture: &mut SegmentFixture,
    stream: &[(u16, i64)],
    reps: usize,
) -> Comparison {
    // correctness gate: identical simulated cycles before any timing
    let (_, c_ref) = fixture.time_ref(stream);
    let (_, c_dec) = fixture.time_decoded(stream);
    assert_eq!(
        c_ref, c_dec,
        "{name}: decoded and reference interpreters disagree on simulated cycles"
    );
    // interleave reps so thermal/frequency drift hits both sides equally
    let mut ref_s = Vec::with_capacity(reps);
    let mut dec_s = Vec::with_capacity(reps);
    for _ in 0..reps {
        ref_s.push(fixture.time_ref(stream).0);
        dec_s.push(fixture.time_decoded(stream).0);
    }
    let r = Summary::of(&ref_s).median;
    let d = Summary::of(&dec_s).median;
    Comparison {
        name,
        ref_median_s: r,
        decoded_median_s: d,
        speedup: r / d,
    }
}

/// End-to-end scheduler run (decoded path only): fib(24) on 256 warps.
fn end_to_end_fib(reps: usize) -> f64 {
    let samples: Vec<f64> = (0..reps)
        .map(|i| {
            let cfg = GtapConfig {
                grid_size: 256,
                block_size: 32,
                seed: 0xBE5E_ED00 + i as u64,
                ..Default::default()
            };
            let mut s = Session::compile(FIB_SRC, cfg, DeviceSpec::h100()).unwrap();
            let t = Instant::now();
            let stats = s.run("fib", &[Value::from_i64(24)]).unwrap();
            assert_eq!(stats.root_result.unwrap().as_i64(), 46368);
            t.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&samples).median
}

fn repo_root() -> PathBuf {
    // crate manifest dir is <repo>/rust; the workspace root is its parent
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

fn main() {
    let reps = sweep::runs();
    println!("hotpath microbench: {SEGMENTS} segments/rep, {reps} reps\n");

    let mut fib = SegmentFixture::new(FIB_SRC, "fib", 0);
    fib.attach_children();
    let fib_cmp = compare("fib_segments", &mut fib, &fib_stream(), reps);

    // tree is void: its continuation reads no child results, so no child
    // records need attaching
    let tree_src = gtap::workloads::tree::full_tree_source(16, 64);
    let mut tree = SegmentFixture::new(&tree_src, "tree", 1);
    let tree_cmp = compare("tree_segments", &mut tree, &tree_stream(), reps);

    let e2e = end_to_end_fib(reps);

    for c in [&fib_cmp, &tree_cmp] {
        println!(
            "{:14} ref {:.4e} s  decoded {:.4e} s  speedup {:.2}x",
            c.name, c.ref_median_s, c.decoded_median_s, c.speedup
        );
    }
    println!("fib(24) end-to-end (decoded scheduler): {e2e:.4e} s median");

    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"measured\": true,\n  \
         \"command\": \"cargo bench --bench hotpath\",\n  \
         \"segments_per_rep\": {SEGMENTS},\n  \"runs\": {reps},\n  \
         \"results\": {{\n    \
         \"fib_segments\": {{\"ref_median_s\": {:.6e}, \"decoded_median_s\": {:.6e}, \"speedup\": {:.3}}},\n    \
         \"tree_segments\": {{\"ref_median_s\": {:.6e}, \"decoded_median_s\": {:.6e}, \"speedup\": {:.3}}},\n    \
         \"fib24_end_to_end\": {{\"decoded_median_s\": {:.6e}}}\n  }}\n}}\n",
        fib_cmp.ref_median_s,
        fib_cmp.decoded_median_s,
        fib_cmp.speedup,
        tree_cmp.ref_median_s,
        tree_cmp.decoded_median_s,
        tree_cmp.speedup,
        e2e,
    );
    let path = repo_root().join("BENCH_hotpath.json");
    std::fs::write(&path, json).expect("write BENCH_hotpath.json");
    println!("\nwrote {}", path.display());
}
