//! Design-choice ablations beyond the paper's own (DESIGN.md calls these
//! out): quantify each mechanism's contribution on Fibonacci and the
//! synthetic tree.
//!
//! Part 1 — single-knob variants against the baseline:
//!
//! 1. **Immediate-execution buffer** (§4.3.2 "keeps up to 32 newly
//!    generated tasks for immediate execution"): disabling routes every
//!    child through the deque — extra push/pop traffic per task.
//! 2. **Steal batch size** (Algorithm 1's `max_count_to_pop` on the steal
//!    side): steal-one (classic Chase–Lev discipline) and steal-half vs
//!    stealing a full warp batch (`PolicyConfig::steal_amount`).
//! 3. **Hierarchical locality-aware stealing** (paper §7 future work):
//!    probe same-SM victims first; intra-SM steals are cheaper (one L2
//!    slice). Now `VictimSelect::LocalityFirst`.
//! 4. **Occupancy-guided stealing**: two-choice victim sampling by queue
//!    occupancy (`VictimSelect::OccupancyGuided`).
//! 5. **Queue-select / placement / backoff** variants of the policy layer.
//! 6. **Adaptive steal sizing** (`StealAmount::Adaptive`): batch vs half
//!    switched online from the observed steal-failure rate.
//! 7. **Per-SM hierarchical tier** (`SmTier::Share`): an SM-shared pool
//!    between own deques and remote victims.
//! 8. **Depth-priority scheduling** (`QueueSelect::Priority` +
//!    `Placement::PriorityDepth` over 4 bands): Atos-style phase/depth
//!    ordering instead of EPAQ path classes (note: this variant also turns
//!    on 4 queues, so it measures the pair against the 1-queue baseline).
//!
//! Part 2 — the policy matrix: every (QueueSelect × VictimSelect ×
//! StealAmount) combination, so interactions (not just main effects) are
//! measurable. Placement, backoff and the SM tier stay at their defaults
//! in the matrix to keep it readable; their main effects are covered in
//! part 1.
//!
//! Part 3 — EPAQ locality under `--memsys modeled`: per-queue-class L1
//! hit rates (`RunStats::memsys_by_class`) with EPAQ path-class placement
//! vs the path-blind default on the same queue count, making the paper's
//! locality claim for path-class queues directly measurable.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::{self, full_scale, measure};
use gtap::coordinator::{
    Backoff, Placement, PolicyConfig, QueueSelect, RunStats, SmTier, StealAmount, VictimSelect,
};
use gtap::sim::MemSysMode;
use gtap::util::stats::Summary;
use std::path::PathBuf;

fn repo_root() -> PathBuf {
    // crate manifest dir is <repo>/rust; the workspace root is its parent
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

fn main() {
    // GTAP_BENCH_SMOKE=1 (the CI smoke-bench job) shrinks problem sizes so
    // the policy-matrix table is recorded on every run; full_scale() keeps
    // the paper-scale sweep for toolchain machines.
    let smoke = std::env::var("GTAP_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let fib_n = if full_scale() {
        30
    } else if smoke {
        20
    } else {
        26
    };
    let tree_d = if full_scale() {
        16
    } else if smoke {
        9
    } else {
        12
    };
    let grid = if smoke { 64 } else { 250 };

    let variants: Vec<(&str, Box<dyn Fn(Exec) -> Exec + Sync>)> = vec![
        ("baseline", Box::new(|e: Exec| e)),
        (
            "no-immediate-buffer",
            Box::new(|mut e: Exec| {
                e.cfg.immediate_buffer = false;
                e
            }),
        ),
        (
            "steal-one",
            Box::new(|e: Exec| e.steal_amount(StealAmount::Fixed { max: Some(1) })),
        ),
        (
            "steal-half",
            Box::new(|e: Exec| e.steal_amount(StealAmount::Half)),
        ),
        (
            "locality-aware-steal",
            Box::new(|e: Exec| e.victim(VictimSelect::LocalityFirst)),
        ),
        (
            "occupancy-steal",
            Box::new(|e: Exec| e.victim(VictimSelect::OccupancyGuided)),
        ),
        (
            "longest-first-queue",
            Box::new(|e: Exec| e.queue_select(QueueSelect::LongestFirst)),
        ),
        (
            "own-queue-placement",
            Box::new(|e: Exec| e.placement(Placement::OwnQueue)),
        ),
        (
            "fixed-poll-backoff",
            Box::new(|e: Exec| e.backoff(Backoff::FixedPoll)),
        ),
        (
            "adaptive-steal",
            Box::new(|e: Exec| e.steal_amount(StealAmount::Adaptive)),
        ),
        (
            "sm-tier-share",
            Box::new(|e: Exec| e.sm_tier(SmTier::Share)),
        ),
        (
            "priority-depth-4q",
            Box::new(|e: Exec| {
                e.queues(4)
                    .queue_select(QueueSelect::Priority)
                    .placement(Placement::PriorityDepth)
            }),
        ),
        // the promoted combination behind `--policy recommended`
        // (PolicyConfig::recommended, sourced from this file's recorded
        // policy_matrix.best): its delta vs baseline stays measured here
        (
            "recommended-policy",
            Box::new(|e: Exec| e.policy(PolicyConfig::recommended())),
        ),
    ];

    let benches: Vec<(&str, Box<dyn Fn(&Exec) -> f64 + Sync>)> = vec![
        (
            "fib",
            Box::new(move |e: &Exec| runners::run_fib(e, fib_n, 0, false).unwrap().seconds),
        ),
        (
            "tree",
            Box::new(move |e: &Exec| {
                runners::run_full_tree(e, tree_d, 64, 256, None).unwrap().seconds
            }),
        ),
    ];

    let mut series: Vec<Series> = vec![];
    for (bname, run) in &benches {
        let mut points: Vec<(f64, Summary)> = vec![];
        let mut baseline_median = 0.0;
        println!("\n## ablations_{bname}\n");
        for (i, (vname, tweak)) in variants.iter().enumerate() {
            let s = measure(|seed| run(&tweak(Exec::gpu_thread(grid, 32).seed(seed))));
            if i == 0 {
                baseline_median = s.median;
            }
            println!(
                "  {vname:22} {:.4e} s  ({:+.1}% vs baseline)",
                s.median,
                100.0 * (s.median - baseline_median) / baseline_median
            );
            points.push((i as f64, s));
        }
        series.push(Series {
            label: bname.to_string(),
            points,
        });
    }
    println!(
        "\n(variant index: 0=baseline, 1=no-immediate-buffer, 2=steal-one, \
         3=steal-half, 4=locality-aware, 5=occupancy, 6=longest-first, \
         7=own-queue, 8=fixed-poll, 9=adaptive-steal, 10=sm-tier-share, \
         11=priority-depth-4q, 12=recommended-policy)\n"
    );
    println!("{}", markdown_table("variant", &series));
    let p = write_csv("ablations", &series).unwrap();
    println!("wrote {}", p.display());

    // ---- part 2: the policy matrix -------------------------------------
    // EPAQ (3 queues) so queue selection has something to select between;
    // 4 queue-selects × 3 victims × 4 steal amounts = 48 combinations.
    println!("\n## policy_matrix (fib, EPAQ 3 queues)\n");
    let combos = PolicyConfig::steal_matrix();
    let mut matrix: Vec<(f64, Summary)> = vec![];
    let mut default_median = 0.0;
    for (i, p) in combos.iter().enumerate() {
        let s = measure(|seed| {
            runners::run_fib(
                &Exec::gpu_thread(grid, 32).queues(3).seed(seed).policy(*p),
                fib_n,
                10,
                true,
            )
            .unwrap()
            .seconds
        });
        if *p == PolicyConfig::default() {
            default_median = s.median;
        }
        println!("  {:28} {:.4e} s", p.label(), s.median);
        matrix.push((i as f64, s));
    }
    if default_median > 0.0 {
        let best = matrix
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.median.total_cmp(&b.1 .1.median))
            .unwrap();
        println!(
            "\n  best combo: {} ({:+.1}% vs default)",
            combos[best.0].label(),
            100.0 * (best.1 .1.median - default_median) / default_median
        );
    }
    let p = write_csv(
        "ablations_policy_matrix",
        &[Series {
            label: "fib-epaq3".to_string(),
            points: matrix.clone(),
        }],
    )
    .unwrap();
    println!("wrote {}", p.display());

    // ---- part 3: EPAQ locality under the modeled memory system ---------
    // EPAQ's locality claim, made measurable: with path-class queues a
    // warp's acquired batch shares one dynamic path, so its coalesced
    // transactions should hit L1 more often than batches drawn from
    // path-blind queues. Both runs use 3 queues and `--memsys modeled`;
    // only placement differs (EPAQ path classes vs the default).
    // `RunStats::memsys_by_class` attributes each warp's traffic to the
    // queue class its batch was acquired from; the modeled pipeline is
    // deterministic per seed, so one run per side suffices.
    println!("\n## epaq_locality (fib, --memsys modeled, 3 queues)\n");
    let modeled_fib = |epaq: bool| -> RunStats {
        runners::run_fib(
            &Exec::gpu_thread(grid, 32)
                .queues(3)
                .memsys(MemSysMode::Modeled)
                .seed(11),
            fib_n,
            10,
            epaq,
        )
        .unwrap()
        .stats
    };
    let epaq_stats = modeled_fib(true);
    let base_stats = modeled_fib(false);
    let rate = |s: &RunStats, q: usize| s.memsys_by_class.get(q).and_then(|c| c.l1_hit_rate());
    let pct = |r: Option<f64>| {
        r.map(|v| format!("{:.1}%", v * 100.0))
            .unwrap_or_else(|| "-".to_string())
    };
    let classes = epaq_stats
        .memsys_by_class
        .len()
        .max(base_stats.memsys_by_class.len());
    for q in 0..classes {
        let (e, b) = (rate(&epaq_stats, q), rate(&base_stats, q));
        let delta = e
            .zip(b)
            .map(|(e, b)| format!("{:+.1} pts", 100.0 * (e - b)))
            .unwrap_or_else(|| "n/a".to_string());
        println!(
            "  class {q}: epaq L1 {}  default L1 {}  ({delta})",
            pct(e),
            pct(b)
        );
    }
    println!(
        "  overall: epaq L1 {}  default L1 {}",
        pct(epaq_stats.memsys.l1_hit_rate()),
        pct(base_stats.memsys.l1_hit_rate())
    );

    // ---- machine-readable record: BENCH_ablations.json -----------------
    // The ROADMAP "policy-matrix perf table" is recorded by CI from this
    // file instead of by hand; `variants` holds the single-knob medians,
    // `policy_matrix` the full QueueSelect × VictimSelect × StealAmount
    // sweep with the best non-default combo called out.
    let variant_names: Vec<&str> = variants.iter().map(|(n, _)| *n).collect();
    let mut var_json = String::new();
    for s in &series {
        if !var_json.is_empty() {
            var_json.push_str(",\n");
        }
        let baseline = s.points[0].1.median;
        let entries: Vec<String> = variant_names
            .iter()
            .zip(s.points.iter())
            .map(|(name, (_, sum))| {
                format!(
                    "      {{\"variant\": \"{}\", \"median_s\": {:.6e}, \
                     \"vs_baseline_pct\": {:.2}}}",
                    name,
                    sum.median,
                    100.0 * (sum.median - baseline) / baseline
                )
            })
            .collect();
        var_json.push_str(&format!(
            "    \"{}\": [\n{}\n    ]",
            s.label,
            entries.join(",\n")
        ));
    }
    let combo_json: Vec<String> = combos
        .iter()
        .zip(matrix.iter())
        .map(|(p, (_, sum))| {
            format!(
                "      {{\"combo\": \"{}\", \"median_s\": {:.6e}, \"default\": {}}}",
                p.label(),
                sum.median,
                *p == PolicyConfig::default()
            )
        })
        .collect();
    let best = matrix
        .iter()
        .enumerate()
        .min_by(|a, b| a.1 .1.median.total_cmp(&b.1 .1.median))
        .expect("matrix is non-empty");
    // Reconcile the promoted bundle against this run's measured best: the
    // `recommended` block makes the check CI-visible so the
    // `PolicyConfig::recommended` pick is either confirmed or flagged by
    // every recorded sweep instead of drifting silently (ROADMAP:
    // "policy-matrix perf table" follow-through).
    let recommended = PolicyConfig::recommended();
    let rec_median = combos
        .iter()
        .position(|p| *p == recommended)
        .map(|i| matrix[i].1.median);
    let rec_matches = combos[best.0] == recommended;
    if !rec_matches {
        println!(
            "  NOTE: recommended bundle {} is not this run's best ({})",
            recommended.label(),
            combos[best.0].label()
        );
    }
    let rate_json = |r: Option<f64>| {
        r.map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "null".to_string())
    };
    let class_rates = |s: &RunStats| {
        (0..classes)
            .map(|q| rate_json(rate(s, q)))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let epaq_json = format!(
        "  \"epaq_locality\": {{\n    \
         \"workload\": \"fib\", \"memsys\": \"modeled\", \"queues\": 3,\n    \
         \"epaq_l1_by_class\": [{}],\n    \
         \"default_l1_by_class\": [{}],\n    \
         \"epaq_overall_l1\": {}, \"default_overall_l1\": {}\n  }}",
        class_rates(&epaq_stats),
        class_rates(&base_stats),
        rate_json(epaq_stats.memsys.l1_hit_rate()),
        rate_json(base_stats.memsys.l1_hit_rate()),
    );
    let json = format!(
        "{{\n  \"bench\": \"ablations\",\n  \"measured\": true,\n  \
         \"command\": \"cargo bench --bench ablations\",\n  \
         \"runs\": {},\n  \"smoke\": {},\n  \
         \"sizes\": {{\"fib_n\": {}, \"tree_depth\": {}, \"grid\": {}}},\n  \
         \"variants\": {{\n{}\n  }},\n  \
         \"policy_matrix\": {{\n    \"workload\": \"fib-epaq3\",\n    \
         \"default_median_s\": {:.6e},\n    \
         \"best\": {{\"combo\": \"{}\", \"median_s\": {:.6e}}},\n    \
         \"recommended\": {{\"combo\": \"{}\", \"median_s\": {}, \
         \"matches_best\": {}}},\n    \
         \"combos\": [\n{}\n    ]\n  }},\n{}\n}}\n",
        sweep::runs(),
        smoke,
        fib_n,
        tree_d,
        grid,
        var_json,
        default_median,
        combos[best.0].label(),
        best.1 .1.median,
        recommended.label(),
        rec_median
            .map(|m| format!("{m:.6e}"))
            .unwrap_or_else(|| "null".to_string()),
        rec_matches,
        combo_json.join(",\n"),
        epaq_json,
    );
    let path = repo_root().join("BENCH_ablations.json");
    std::fs::write(&path, json).expect("write BENCH_ablations.json");
    println!("wrote {}", path.display());
}
