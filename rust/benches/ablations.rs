//! Design-choice ablations beyond the paper's own (DESIGN.md calls these
//! out): quantify each mechanism's contribution on Fibonacci and the
//! synthetic tree.
//!
//! 1. **Immediate-execution buffer** (§4.3.2 "keeps up to 32 newly
//!    generated tasks for immediate execution"): disabling routes every
//!    child through the deque — extra push/pop traffic per task.
//! 2. **Steal batch size** (Algorithm 1's `max_count_to_pop` on the steal
//!    side): steal-one (classic Chase–Lev discipline) vs stealing a full
//!    warp batch.
//! 3. **Hierarchical locality-aware stealing** (paper §7 future work):
//!    probe same-SM victims first; intra-SM steals are cheaper (one L2
//!    slice). Implemented as `GtapConfig::locality_aware_steal`.

use gtap::bench::emit::{markdown_table, write_csv, Series};
use gtap::bench::runners::{self, Exec};
use gtap::bench::sweep::{full_scale, measure};
use gtap::util::stats::Summary;

fn main() {
    let fib_n = if full_scale() { 30 } else { 26 };
    let tree_d = if full_scale() { 16 } else { 12 };
    let grid = 250;

    let variants: Vec<(&str, Box<dyn Fn(Exec) -> Exec + Sync>)> = vec![
        ("baseline", Box::new(|e: Exec| e)),
        (
            "no-immediate-buffer",
            Box::new(|mut e: Exec| {
                e.cfg.immediate_buffer = false;
                e
            }),
        ),
        (
            "steal-one",
            Box::new(|mut e: Exec| {
                e.cfg.steal_max = Some(1);
                e
            }),
        ),
        (
            "locality-aware-steal",
            Box::new(|mut e: Exec| {
                e.cfg.locality_aware_steal = true;
                e
            }),
        ),
    ];

    let benches: Vec<(&str, Box<dyn Fn(&Exec) -> f64 + Sync>)> = vec![
        (
            "fib",
            Box::new(move |e: &Exec| runners::run_fib(e, fib_n, 0, false).unwrap().seconds),
        ),
        (
            "tree",
            Box::new(move |e: &Exec| {
                runners::run_full_tree(e, tree_d, 64, 256, None).unwrap().seconds
            }),
        ),
    ];

    let mut series: Vec<Series> = vec![];
    for (bname, run) in &benches {
        let mut points: Vec<(f64, Summary)> = vec![];
        let mut baseline_median = 0.0;
        println!("\n## ablations_{bname}\n");
        for (i, (vname, tweak)) in variants.iter().enumerate() {
            let s = measure(|seed| run(&tweak(Exec::gpu_thread(grid, 32).seed(seed))));
            if i == 0 {
                baseline_median = s.median;
            }
            println!(
                "  {vname:22} {:.4e} s  ({:+.1}% vs baseline)",
                s.median,
                100.0 * (s.median - baseline_median) / baseline_median
            );
            points.push((i as f64, s));
        }
        series.push(Series {
            label: bname.to_string(),
            points,
        });
    }
    println!("\n(variant index: 0=baseline, 1=no-immediate-buffer, 2=steal-one, 3=locality-aware)\n");
    println!("{}", markdown_table("variant", &series));
    let p = write_csv("ablations", &series).unwrap();
    println!("wrote {}", p.display());
}
